"""Graph containers, format conversions, and delta-overlay storage.

The framework stores graphs in COO form (host-side ``numpy``), and derives:

* CSR / CSC views for host-side traversal and neighbor sampling,
* symmetrized (undirected) edge lists for diffusion (DiDiC operates on
  undirected weighted graphs, paper §3.2),
* a padded block-ELL (BELL) layout — block-sparse adjacency with
  MXU-aligned dense blocks — consumed by the ``bsr_spmm`` Pallas kernel.

Device arrays are produced on demand; the canonical representation stays on
host so multi-million-edge graphs never pay device transfer until needed.

Growing graphs use a **base + delta overlay** (:class:`GraphStore`), the
classic dynamic-graph-storage layout (Besta et al., *Demystifying Graph
Databases*). A store fixes a vertex capacity ``n_cap >= n_nodes`` and an
edge capacity ``e_cap >= n_edges`` when growth begins; every device layout
derived from a store-backed graph (BFS prefix tables, gather/scatter edge
lists, DiDiC diffusion state) is padded to capacity with an inert tail —
dead rows receive zero mass, dead edges point at a sentinel row — so vertex
and edge inserts only advance an append cursor and refresh device buffers
*without changing any compiled shape*. Compiled programs therefore survive
growth: jitted closures are cached on the store (keyed by capacity, mesh,
and engine parameters, not by graph object identity) and adopt each grown
graph in place. When an insert would overflow the delta, the lineage
**compacts**: a fresh base is cut at the grown extents, a new store with
fresh headroom is allocated, and ``compactions`` is incremented — the one
amortized rebuild (and retrace) the overlay design allows. The host COO
arrays remain the logical truth at every step; capacities only govern
device-side padding, so host-path results are unchanged bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import os
from functools import cached_property
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "Graph",
    "GraphStore",
    "GROWTH_HEADROOM",
    "BlockEll",
    "PaddedNeighbors",
    "coalesce_edges",
    "symmetrize",
    "padded_neighbors",
]

# Capacity multiplier applied when a store is allocated (at growth onset and
# at every compaction): a delta sized to ``headroom - 1`` times the current
# extents absorbs that much relative growth before the next compaction.
# Default only — override per store (``GraphStore(headroom=...)`` /
# ``Graph.ensure_store(headroom=...)``) or process-wide via the
# ``REPRO_GROWTH_HEADROOM`` env var, the HBM-calibration knob: padded
# capacity costs device memory linearly, so an accelerator run that knows
# its growth schedule can trade compaction frequency against footprint.
GROWTH_HEADROOM = 2.0


def _resolve_headroom(headroom: Optional[float] = None) -> float:
    if headroom is None:
        headroom = float(os.environ.get("REPRO_GROWTH_HEADROOM", GROWTH_HEADROOM))
    headroom = float(headroom)
    if headroom < 1.0:
        raise ValueError(f"growth headroom must be >= 1.0, got {headroom}")
    return headroom


class GraphStore:
    """Delta-overlay control block shared along one growing graph lineage.

    The store pins the padded device capacity (``n_cap`` rows / ``e_cap``
    edge slots) that every overlay layout is built to, records the base
    extents the current delta accumulates on top of (``base_nodes`` /
    ``base_edges``; the delta cursors are ``graph.n_nodes - base_nodes``
    and ``graph.n_edges - base_edges``), and counts ``compactions``. It
    also owns ``caches`` — jitted engines/replayers/programs keyed by
    (capacity, mesh, axes, engine params) live here instead of on the
    graph object, so a grown graph (a *new* ``Graph``) reuses the same
    compiled closures by adopting them in place.

    The store never holds graph data itself: host COO arrays on the
    ``Graph`` are the logical truth, and overlay consumers re-upload the
    capacity-padded device buffers from them on adoption.
    """

    def __init__(
        self,
        n_cap: int,
        e_cap: int,
        base_nodes: int,
        base_edges: int,
        compactions: int = 0,
        headroom: Optional[float] = None,
    ) -> None:
        self.n_cap = int(n_cap)
        self.e_cap = int(e_cap)
        self.base_nodes = int(base_nodes)
        self.base_edges = int(base_edges)
        self.compactions = int(compactions)
        # The store remembers its headroom so a compaction re-derives
        # capacity with the multiplier this lineage was configured with,
        # not whatever the process default happens to be at that moment.
        self.headroom = _resolve_headroom(headroom)
        self.caches: Dict = {}

    def would_overflow(self, graph: "Graph", n_new_vertices: int, n_new_edges: int) -> bool:
        """True if appending the given counts to ``graph`` exceeds capacity."""
        return (
            graph.n_nodes + int(n_new_vertices) > self.n_cap
            or graph.n_edges + int(n_new_edges) > self.e_cap
        )

    def delta_nodes(self, graph: "Graph") -> int:
        """Vertex append cursor: rows of ``graph`` living in the delta."""
        return graph.n_nodes - self.base_nodes

    def delta_edges(self, graph: "Graph") -> int:
        """Edge append cursor: edge slots of ``graph`` living in the delta."""
        return graph.n_edges - self.base_edges

    def _carry_to(self, old_graph: "Graph", new_graph: "Graph") -> None:
        """Attach this store to a grown graph, compacting on overflow.

        On overflow the old base + old delta (``old_graph``'s extents)
        are folded into the fresh base, and the overflowing insert lands
        in the fresh delta — capacities are re-derived with headroom
        from the *grown* extents so the new delta starts with room.
        """
        if new_graph.n_nodes <= self.n_cap and new_graph.n_edges <= self.e_cap:
            new_graph.store = self
        else:
            new_graph.store = GraphStore(
                n_cap=_with_headroom(new_graph.n_nodes, self.headroom),
                e_cap=_with_headroom(new_graph.n_edges, self.headroom),
                base_nodes=old_graph.n_nodes,
                base_edges=old_graph.n_edges,
                compactions=self.compactions + 1,
                headroom=self.headroom,
            )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"GraphStore(n_cap={self.n_cap}, e_cap={self.e_cap}, "
            f"base={self.base_nodes}/{self.base_edges}, "
            f"compactions={self.compactions})"
        )


def _with_headroom(extent: int, headroom: Optional[float] = None) -> int:
    return int(np.ceil(_resolve_headroom(headroom) * max(int(extent), 1)))


def coalesce_edges(
    senders: np.ndarray,
    receivers: np.ndarray,
    weights: Optional[np.ndarray],
    n_nodes: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort edges by (sender, receiver), merge duplicates (summing weights)."""
    senders = np.asarray(senders, dtype=np.int64)
    receivers = np.asarray(receivers, dtype=np.int64)
    if weights is None:
        weights = np.ones(senders.shape[0], dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    key = senders * n_nodes + receivers
    order = np.argsort(key, kind="stable")
    key, senders, receivers, weights = key[order], senders[order], receivers[order], weights[order]
    uniq, inv = np.unique(key, return_inverse=True)
    merged_w = np.zeros(uniq.shape[0], dtype=np.float32)
    np.add.at(merged_w, inv, weights)
    first = np.searchsorted(key, uniq)
    return senders[first].astype(np.int32), receivers[first].astype(np.int32), merged_w


def symmetrize(
    senders: np.ndarray, receivers: np.ndarray, weights: np.ndarray, n_nodes: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Return the undirected (symmetrized, coalesced, loop-free) edge set."""
    s = np.concatenate([senders, receivers])
    r = np.concatenate([receivers, senders])
    w = np.concatenate([weights, weights])
    keep = s != r
    return coalesce_edges(s[keep], r[keep], w[keep], n_nodes)


@dataclasses.dataclass(frozen=True)
class BlockEll:
    """Padded block-ELL (a.k.a. BELL) block-sparse matrix layout.

    ``blocks[i, j]`` is the dense ``(bs, bs)`` block at block-row ``i``, slot
    ``j``; ``block_cols[i, j]`` its block-column (or ``-1`` for padding). The
    layout is rectangular so a Pallas grid can walk it with scalar-prefetched
    indices; padded slots carry zero blocks and column index 0 with a zero
    mask so arithmetic stays branch-free.
    """

    blocks: np.ndarray       # [n_block_rows, max_nnzb, bs, bs] float32
    block_cols: np.ndarray   # [n_block_rows, max_nnzb] int32 (0 where padded)
    block_mask: np.ndarray   # [n_block_rows, max_nnzb] float32 {0,1}
    n_rows: int              # logical (unpadded) row count
    n_cols: int
    block_size: int

    @property
    def n_block_rows(self) -> int:
        return self.blocks.shape[0]

    @property
    def max_nnzb(self) -> int:
        return self.blocks.shape[1]

    @property
    def padded_rows(self) -> int:
        return self.n_block_rows * self.block_size

    def density(self) -> float:
        nnzb = float(self.block_mask.sum())
        total = (self.padded_rows / self.block_size) ** 2
        return nnzb / max(total, 1.0)

    def to_dense(self) -> np.ndarray:
        bs = self.block_size
        out = np.zeros((self.padded_rows, self.padded_rows), dtype=self.blocks.dtype)
        for i in range(self.n_block_rows):
            for j in range(self.max_nnzb):
                if self.block_mask[i, j] > 0:
                    c = int(self.block_cols[i, j])
                    out[i * bs:(i + 1) * bs, c * bs:(c + 1) * bs] += self.blocks[i, j]
        return out[: self.n_rows, : self.n_cols]


@dataclasses.dataclass(frozen=True)
class PaddedNeighbors:
    """Rectangular (ELL-style) *gather* layout of an edge set.

    Row ``v`` lists the in-neighbors of ``v`` — every edge ``u → v`` puts
    ``u`` in ``nbr[v]`` — padded to the max in-degree so a kernel grid (or a
    single vectorized gather) can walk it with static shapes. Padded slots
    carry index 0 and mask 0, so ``sum_j mask[v,j]·x[nbr[v,j]]`` is one
    frontier/SpMV step as a pure gather — no scatter, which is what the
    ``repro.kernels.frontier`` Pallas kernel wants on the MXU/VPU.

    When built with a slot ``cap`` below the max in-degree, edges beyond
    the cap live in the COO ``spill_*`` tail (empty arrays otherwise) —
    the work-efficient shape for skewed degree distributions, where one
    scatter over the tail beats padding every row to a hub's degree.
    """

    nbr: np.ndarray      # [N, D] int32 in-neighbor ids (0 where padded)
    w: np.ndarray        # [N, D] float32 edge weights (0 where padded)
    mask: np.ndarray     # [N, D] float32 {0, 1}
    spill_s: np.ndarray  # [S] int32 senders of over-cap edges
    spill_r: np.ndarray  # [S] int32 receivers of over-cap edges
    spill_w: np.ndarray  # [S] float32 weights of over-cap edges

    @property
    def n_nodes(self) -> int:
        return self.nbr.shape[0]

    @property
    def max_deg(self) -> int:
        return self.nbr.shape[1]

    @property
    def n_spill(self) -> int:
        return self.spill_s.shape[0]


def padded_neighbors(
    senders: np.ndarray,
    receivers: np.ndarray,
    weights: Optional[np.ndarray],
    n_nodes: int,
    cap: Optional[int] = None,
) -> PaddedNeighbors:
    """Pack an edge list into the :class:`PaddedNeighbors` gather layout.

    ``cap`` bounds the slot axis; edges past it spill into the COO tail.
    """
    senders = np.asarray(senders, dtype=np.int64)
    receivers = np.asarray(receivers, dtype=np.int64)
    if weights is None:
        weights = np.ones(senders.shape[0], dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    counts = np.bincount(receivers, minlength=n_nodes)
    d = max(int(counts.max(initial=0)), 1)
    if cap is not None:
        d = min(d, max(int(cap), 1))
    order = np.argsort(receivers, kind="stable")
    r_sorted = receivers[order]
    s_sorted = senders[order]
    w_sorted = weights[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(r_sorted.shape[0], dtype=np.int64) - starts[r_sorted]
    main = slot < d
    nbr = np.zeros((n_nodes, d), dtype=np.int32)
    w = np.zeros((n_nodes, d), dtype=np.float32)
    mask = np.zeros((n_nodes, d), dtype=np.float32)
    nbr[r_sorted[main], slot[main]] = s_sorted[main].astype(np.int32)
    w[r_sorted[main], slot[main]] = w_sorted[main]
    mask[r_sorted[main], slot[main]] = 1.0
    sp = ~main
    return PaddedNeighbors(
        nbr=nbr, w=w, mask=mask,
        spill_s=s_sorted[sp].astype(np.int32),
        spill_r=r_sorted[sp].astype(np.int32),
        spill_w=w_sorted[sp],
    )


@dataclasses.dataclass
class Graph:
    """A directed, weighted multigraph with optional node metadata.

    ``senders[e] -> receivers[e]`` with weight ``edge_weight[e]``. Node
    metadata (``node_type``, coordinates, ...) lives in ``node_attrs`` — the
    generators populate what their access patterns / hardcoded partitioners
    need (paper §6.2).
    """

    n_nodes: int
    senders: np.ndarray            # [E] int32
    receivers: np.ndarray          # [E] int32
    edge_weight: np.ndarray        # [E] float32
    node_attrs: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    name: str = "graph"
    store: Optional[GraphStore] = dataclasses.field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.senders = np.asarray(self.senders, dtype=np.int32)
        self.receivers = np.asarray(self.receivers, dtype=np.int32)
        if self.edge_weight is None:
            self.edge_weight = np.ones(self.senders.shape[0], dtype=np.float32)
        self.edge_weight = np.asarray(self.edge_weight, dtype=np.float32)
        assert self.senders.shape == self.receivers.shape == self.edge_weight.shape

    # ------------------------------------------------------------------ basic
    @property
    def n_edges(self) -> int:
        return int(self.senders.shape[0])

    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.bincount(self.senders, minlength=self.n_nodes).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.receivers, minlength=self.n_nodes).astype(np.int32)

    @cached_property
    def degree(self) -> np.ndarray:
        return self.out_degree + self.in_degree

    # ------------------------------------------------------- undirected view
    @cached_property
    def undirected(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(senders, receivers, weights) of the symmetrized loop-free graph.

        Both edge directions are present, so ``segment_sum`` over this list
        implements one full undirected neighbor reduction — the primitive of
        DiDiC diffusion (paper Eq. 4.6/4.7).
        """
        return symmetrize(self.senders, self.receivers, self.edge_weight, self.n_nodes)

    @cached_property
    def weighted_degree(self) -> np.ndarray:
        """d(v) = sum of undirected incident edge weights (paper Eq. 3.4)."""
        s, _, w = self.undirected
        d = np.zeros(self.n_nodes, dtype=np.float64)
        np.add.at(d, s, w)
        return d.astype(np.float32)

    # ----------------------------------------------------- delta overlay
    def ensure_store(
        self,
        n_cap: Optional[int] = None,
        e_cap: Optional[int] = None,
        headroom: Optional[float] = None,
    ) -> GraphStore:
        """Attach (or return) the delta-overlay store for this lineage.

        Called once when growth begins; the default capacities reserve
        ``headroom`` times the current extents (``headroom`` defaults to
        the ``REPRO_GROWTH_HEADROOM`` env var, then
        :data:`GROWTH_HEADROOM`). Explicit caps (used by
        compaction-boundary tests) must cover the current graph.
        """
        if self.store is not None:
            return self.store
        n_cap = _with_headroom(self.n_nodes, headroom) if n_cap is None else int(n_cap)
        e_cap = _with_headroom(self.n_edges, headroom) if e_cap is None else int(e_cap)
        if n_cap < self.n_nodes or e_cap < self.n_edges:
            raise ValueError(
                f"store capacity ({n_cap}, {e_cap}) below current extents "
                f"({self.n_nodes}, {self.n_edges})"
            )
        self.store = GraphStore(
            n_cap=n_cap, e_cap=e_cap,
            base_nodes=self.n_nodes, base_edges=self.n_edges,
            headroom=headroom,
        )
        return self.store

    # -------------------------------------------------------------- updates
    def with_edges(
        self,
        senders: np.ndarray,
        receivers: np.ndarray,
        weights: Optional[np.ndarray] = None,
    ) -> "Graph":
        """New :class:`Graph` with the given edges appended.

        The node set (and ``node_attrs``, shared by reference) is
        unchanged, so partition maps, evaluation logs, and per-vertex
        state remain valid on the result; every structure-derived cache
        (CSR views, padded layouts, engines) rebuilds lazily on the new
        object. A delta-overlay :class:`GraphStore` is carried forward
        when the result still fits its capacity (store-cached engines
        then adopt the new graph without retracing), and replaced by a
        compacted store otherwise. This is the structural-dynamism
        primitive: a
        :class:`repro.core.dynamism.DynamismLog` carrying edge inserts is
        applied by the graph service through this method.
        """
        senders = np.asarray(senders, dtype=self.senders.dtype)
        receivers = np.asarray(receivers, dtype=self.receivers.dtype)
        if weights is None:
            weights = np.ones(senders.shape[0], dtype=np.float32)
        weights = np.asarray(weights, dtype=np.float32)
        if not (senders.shape == receivers.shape == weights.shape):
            raise ValueError("with_edges arrays must have matching shapes")
        for ends in (senders, receivers):
            if ends.size and (ends.min() < 0 or ends.max() >= self.n_nodes):
                raise ValueError("with_edges endpoints must be existing vertices")
        out = Graph(
            n_nodes=self.n_nodes,
            senders=np.concatenate([self.senders, senders]),
            receivers=np.concatenate([self.receivers, receivers]),
            edge_weight=np.concatenate(
                [self.edge_weight, np.asarray(weights, dtype=np.float32)]
            ),
            node_attrs=self.node_attrs,
            name=self.name,
        )
        if self.store is not None:
            self.store._carry_to(self, out)
        return out

    def with_vertices(
        self,
        n_new: int,
        attrs: Optional[Dict[str, np.ndarray]] = None,
        senders: Optional[np.ndarray] = None,
        receivers: Optional[np.ndarray] = None,
        weights: Optional[np.ndarray] = None,
    ) -> "Graph":
        """New :class:`Graph` with ``n_new`` vertices appended, plus their
        incident edges.

        The new vertices take ids ``n_nodes .. n_nodes + n_new - 1``; edge
        endpoints may reference old or new vertices. ``attrs[key]`` supplies
        the appended rows (shape ``[n_new, ...]``) for per-node metadata;
        keys of ``node_attrs`` not supplied get zero rows of the matching
        dtype (sentinel-valued attrs like ``parent = -1`` must be passed
        explicitly). Attr arrays are reallocated — the old graph and
        everything derived from it stay valid — and every structure cache
        (CSR views, padded layouts, engines) rebuilds lazily on the new
        object. A delta-overlay :class:`GraphStore` is carried forward
        while the result fits its capacity and compacted otherwise, as
        in :meth:`with_edges`. This is the vertex-growth primitive behind
        the Insert
        experiment: a :class:`repro.core.dynamism.DynamismLog` that
        allocates new vertices is applied by the graph service through
        this method.
        """
        n_new = int(n_new)
        if n_new < 0:
            raise ValueError("with_vertices needs n_new >= 0")
        n_total = self.n_nodes + n_new
        attrs = attrs or {}
        unknown = set(attrs) - set(self.node_attrs)
        if unknown:
            raise ValueError(f"with_vertices attrs not in node_attrs: {sorted(unknown)}")
        new_attrs: Dict[str, np.ndarray] = {}
        for key, old in self.node_attrs.items():
            if old.shape[0] != self.n_nodes:
                new_attrs[key] = old  # not per-node metadata; carried as-is
                continue
            rows = attrs.get(key)
            if rows is None:
                rows = np.zeros((n_new,) + old.shape[1:], dtype=old.dtype)
            else:
                rows = np.asarray(rows, dtype=old.dtype)
                if rows.shape != (n_new,) + old.shape[1:]:
                    raise ValueError(
                        f"with_vertices attrs[{key!r}] has shape {rows.shape}, "
                        f"want {(n_new,) + old.shape[1:]}"
                    )
            new_attrs[key] = np.concatenate([old, rows])
        if senders is None:
            senders = np.zeros(0, dtype=self.senders.dtype)
        if receivers is None:
            receivers = np.zeros(0, dtype=self.receivers.dtype)
        senders = np.asarray(senders, dtype=self.senders.dtype)
        receivers = np.asarray(receivers, dtype=self.receivers.dtype)
        if weights is None:
            weights = np.ones(senders.shape[0], dtype=np.float32)
        weights = np.asarray(weights, dtype=np.float32)
        if not (senders.shape == receivers.shape == weights.shape):
            raise ValueError("with_vertices edge arrays must have matching shapes")
        for ends in (senders, receivers):
            if ends.size and (ends.min() < 0 or ends.max() >= n_total):
                raise ValueError(
                    "with_vertices endpoints must be existing or appended vertices"
                )
        out = Graph(
            n_nodes=n_total,
            senders=np.concatenate([self.senders, senders]),
            receivers=np.concatenate([self.receivers, receivers]),
            edge_weight=np.concatenate([self.edge_weight, weights]),
            node_attrs=new_attrs,
            name=self.name,
        )
        if self.store is not None:
            self.store._carry_to(self, out)
        return out

    # ------------------------------------------------------------- CSR views
    @cached_property
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(indptr, indices, weights) over *directed* out-edges."""
        order = np.argsort(self.senders, kind="stable")
        indices = self.receivers[order]
        weights = self.edge_weight[order]
        counts = np.bincount(self.senders, minlength=self.n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return indptr, indices, weights

    @cached_property
    def undirected_csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        s, r, w = self.undirected
        order = np.argsort(s, kind="stable")
        indices = r[order]
        weights = w[order]
        counts = np.bincount(s, minlength=self.n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return indptr, indices, weights

    # ------------------------------------------------------------ BELL view
    def to_block_ell(self, block_size: int = 128, undirected: bool = True) -> BlockEll:
        """Pack the (weighted) adjacency into the BELL layout for ``bsr_spmm``.

        Rows/cols are zero-padded to a multiple of ``block_size``. The block
        at (bi, bj) is dense ``A[bi*bs:(bi+1)*bs, bj*bs:(bj+1)*bs]``. The
        packing is cached per ``(block_size, undirected)`` — static graphs
        (every DiDiC run, every maintenance iteration) pay it exactly once.
        """
        cache = self.__dict__.setdefault("_bell_cache", {})
        key = (block_size, undirected)
        if key in cache:
            return cache[key]
        if undirected:
            s, r, w = self.undirected
        else:
            s, r, w = self.senders, self.receivers, self.edge_weight
        bs = block_size
        nbr = -(-self.n_nodes // bs)  # ceil
        bi = s // bs
        bj = r // bs
        pair = bi.astype(np.int64) * nbr + bj
        uniq_pairs, inv = np.unique(pair, return_inverse=True)
        # per block-row slot assignment
        u_bi = (uniq_pairs // nbr).astype(np.int64)
        u_bj = (uniq_pairs % nbr).astype(np.int64)
        slot_of_pair = np.zeros(uniq_pairs.shape[0], dtype=np.int64)
        row_counts = np.bincount(u_bi, minlength=nbr)
        max_nnzb = max(int(row_counts.max(initial=0)), 1)
        # stable slot index within each block row
        order = np.argsort(u_bi, kind="stable")
        slot_running = np.arange(uniq_pairs.shape[0])
        row_starts = np.concatenate([[0], np.cumsum(row_counts)])
        slot_of_pair[order] = slot_running - row_starts[u_bi[order]]
        blocks = np.zeros((nbr, max_nnzb, bs, bs), dtype=np.float32)
        block_cols = np.zeros((nbr, max_nnzb), dtype=np.int32)
        block_mask = np.zeros((nbr, max_nnzb), dtype=np.float32)
        block_cols[u_bi, slot_of_pair] = u_bj.astype(np.int32)
        block_mask[u_bi, slot_of_pair] = 1.0
        e_slot = slot_of_pair[inv]
        np.add.at(blocks, (bi, e_slot, s % bs, r % bs), w)
        bell = BlockEll(
            blocks=blocks,
            block_cols=block_cols,
            block_mask=block_mask,
            n_rows=self.n_nodes,
            n_cols=self.n_nodes,
            block_size=bs,
        )
        cache[key] = bell
        return bell

    # ------------------------------------------------------------- utilities
    def subgraph(self, node_mask: np.ndarray) -> "Graph":
        """Induced subgraph; nodes renumbered densely."""
        node_mask = np.asarray(node_mask, dtype=bool)
        new_id = np.full(self.n_nodes, -1, dtype=np.int64)
        kept = np.nonzero(node_mask)[0]
        new_id[kept] = np.arange(kept.shape[0])
        e_keep = node_mask[self.senders] & node_mask[self.receivers]
        attrs = {k: v[kept] for k, v in self.node_attrs.items() if v.shape[0] == self.n_nodes}
        return Graph(
            n_nodes=int(kept.shape[0]),
            senders=new_id[self.senders[e_keep]],
            receivers=new_id[self.receivers[e_keep]],
            edge_weight=self.edge_weight[e_keep],
            node_attrs=attrs,
            name=self.name + "_sub",
        )

    def clustering_stats(self, sample: int = 2000, seed: int = 0) -> float:
        """Approximate global clustering coefficient by vertex sampling."""
        indptr, indices, _ = self.undirected_csr
        rng = np.random.default_rng(seed)
        nodes = rng.choice(self.n_nodes, size=min(sample, self.n_nodes), replace=False)
        coeffs = []
        for v in nodes:
            nbrs = indices[indptr[v]:indptr[v + 1]]
            d = nbrs.shape[0]
            if d < 2:
                coeffs.append(0.0)
                continue
            nbr_set = set(nbrs.tolist())
            links = 0
            for u in nbrs:
                row = indices[indptr[u]:indptr[u + 1]]
                links += sum(1 for x in row if int(x) in nbr_set)
            coeffs.append(links / (d * (d - 1)))
        return float(np.mean(coeffs)) if coeffs else 0.0

    def summary(self) -> str:
        return (
            f"Graph({self.name}): |V|={self.n_nodes:,} |E|={self.n_edges:,} "
            f"avg_out_deg={self.n_edges / max(self.n_nodes, 1):.2f}"
        )
