"""Dataset registry: the paper's three graphs + GNN-shape stand-ins.

``load(name, scale)`` is the single entry point used by benchmarks, configs
and examples. Synthetic stand-ins for public GNN datasets (cora, reddit,
ogbn-products) mirror the assigned input-shape statistics; at dry-run time
only ShapeDtypeStructs are used, so the full-size variants never allocate.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.graphs import generators as G
from repro.graphs.structure import Graph

__all__ = ["load", "DATASETS", "SHAPE_STATS"]

# Published statistics for the assigned GNN shapes (used by input_specs()).
SHAPE_STATS = {
    "full_graph_sm": dict(n_nodes=2_708, n_edges=10_556, d_feat=1_433),
    "minibatch_lg": dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1_024, fanout=(15, 10)),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128),
}


def _cora_like(scale: float = 1.0, seed: int = 0) -> Graph:
    n = max(int(2708 * scale), 64)
    g = G.random_graph(n, avg_degree=10556 / 2708, seed=seed)
    g.name = "cora_like"
    return g


def _reddit_like(scale: float = 0.02, seed: int = 0) -> Graph:
    # Full reddit is 115M edges; default scale keeps host memory sane.
    n = max(int(232_965 * scale), 256)
    g = G.twitter_social(scale=n / 611_643, seed=seed)
    g.name = "reddit_like"
    return g


def _products_like(scale: float = 0.01, seed: int = 0) -> Graph:
    """ogbn-products stand-in: strong community structure (co-purchase
    clusters) at the published average degree — a pure random graph would
    make partitioning studies degenerate (no community ⇒ no good cut)."""
    import numpy as np

    n = max(int(2_449_029 * scale), 512)
    avg_degree = 61_859_140 / 2_449_029
    rng = np.random.default_rng(seed)
    comm_size = 500
    comm = rng.permutation(n) // comm_size  # communities of ~500
    e = int(n * avg_degree / 2)
    # 85 % of edges inside a community, 15 % across (SBM-ish)
    n_in = int(e * 0.85)
    s_in = rng.integers(0, n, size=n_in)
    # partner inside the same community
    offs = rng.integers(1, comm_size, size=n_in)
    order = np.argsort(comm, kind="stable")
    pos_in_comm = np.empty(n, dtype=np.int64)
    pos_in_comm[order] = np.arange(n)
    base = pos_in_comm[s_in] - pos_in_comm[s_in] % comm_size
    r_in = order[np.minimum(base + (pos_in_comm[s_in] % comm_size + offs) % comm_size, n - 1)]
    s_out = rng.integers(0, n, size=e - n_in)
    r_out = rng.integers(0, n, size=e - n_in)
    s = np.concatenate([s_in, s_out])
    r = np.concatenate([r_in, r_out])
    keep = s != r
    g = Graph(
        n_nodes=n, senders=s[keep].astype(np.int32), receivers=r[keep].astype(np.int32),
        edge_weight=np.ones(int(keep.sum()), np.float32),
        node_attrs={"community": comm.astype(np.int32)}, name="products_like",
    )
    return g


DATASETS: Dict[str, Callable[..., Graph]] = {
    "filesystem": G.filesystem_tree,
    "gis": G.gis_romania,
    "twitter": G.twitter_social,
    "two_cluster": lambda scale=1.0, seed=0: G.two_cluster(n_per=max(int(64 * scale), 8), seed=seed),
    "cora_like": _cora_like,
    "reddit_like": _reddit_like,
    "products_like": _products_like,
    "molecules": lambda scale=1.0, seed=0: G.molecule_batch(n_mols=max(int(128 * scale), 2), seed=seed),
    "mesh": lambda scale=1.0, seed=0: G.mesh_graph(
        rows=max(int(64 * scale), 8), cols=max(int(64 * scale), 8), seed=seed
    ),
}


def load(name: str, scale: float = 0.1, seed: int = 0) -> Graph:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return DATASETS[name](scale=scale, seed=seed)
