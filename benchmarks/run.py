"""Benchmark entry point — one function per paper table.

Prints ``name,value,derived`` CSV. ``--scale`` / ``--full`` raise dataset
sizes toward the paper's; default finishes on the CPU container in minutes.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01,
                    help="fraction of paper dataset sizes (1.0 = paper)")
    ap.add_argument("--tables", type=str, default="all",
                    help="comma list: 7.1,7.2,static,corr,insert,insert-growth,"
                         "stress,dynamic,maint,kernels,roofline")
    ap.add_argument("--didic-iters", type=int, default=100)
    args = ap.parse_args()

    from benchmarks.paper_tables import PaperBench
    from repro.configs.paper_didic import PaperExperimentConfig

    cfg = PaperExperimentConfig(scale=args.scale, didic_iterations=args.didic_iters)
    bench = PaperBench(cfg)
    want = args.tables.split(",")
    t0 = time.time()

    print("name,value,derived")
    table_map = {
        "7.1": bench.table_7_1,
        "7.2": bench.tables_7_2_to_7_4,
        "static": bench.static_traffic,
        "corr": bench.correlation_check,
        "insert": bench.insert_experiment,
        "insert-growth": bench.insert_growth_experiment,
        "stress": bench.stress_experiment,
        "dynamic": bench.dynamic_experiment,
        "maint": bench.maintenance_cost,
    }
    if "all" in want:
        rows = bench.all_tables()
        for r in rows:
            print(r.csv())
    else:
        for key in want:
            if key in table_map:
                for r in table_map[key]():
                    print(r.csv())

    if "all" in want or "kernels" in want:
        from benchmarks.kernel_bench import bench_rows
        for row in bench_rows():
            print(row)

    if "all" in want or "roofline" in want:
        from benchmarks.roofline import rows_csv
        for row in rows_csv():
            print(row)

    print(f"_total_wall_s,{time.time() - t0:.1f},", file=sys.stdout)


if __name__ == "__main__":
    main()
