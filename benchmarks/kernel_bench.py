"""Kernel microbenchmarks: oracle wall time (CPU) + structural VMEM/roofline
numbers for the Pallas kernels (the TPU target numbers come from §Roofline,
not wall clock — this container is CPU-only), plus the traffic-engine
throughput benchmark (batched JIT engine vs scalar oracle, per pattern).

Usage:
  python -m benchmarks.kernel_bench                 # kernel micro rows
  python -m benchmarks.kernel_bench --traffic       # full traffic bench
  python -m benchmarks.kernel_bench --traffic-smoke # ~5 s regression smoke
  python -m benchmarks.kernel_bench --traffic-dist  # sharded replay bench
      (shard count = visible devices; the Makefile targets force a
      multi-device CPU platform via XLA_FLAGS)
  python -m benchmarks.kernel_bench --traffic-dist-smoke  # ~10 s smoke
  python -m benchmarks.kernel_bench --dynamic       # dynamic-experiment bench
      (host loop vs device runtime, bit-exact parity asserted per slice)
  python -m benchmarks.kernel_bench --dynamic-smoke # parity + rate smoke
  python -m benchmarks.kernel_bench --dynamic-resident-smoke  # resident replay
      parity smoke: cold vs resident bit-equality per slice, plus a
      structural-insert partial-redo leg
  python -m benchmarks.kernel_bench --insert-smoke  # vertex-growth Insert
      workload: 20x5% schedule with new-vertex inserts, resident vs cold
      bit-equality under both policies + structural slice round-trip
  python -m benchmarks.kernel_bench --grow-steady-smoke  # zero-recompile
      growth gate: the sentinel's 20x5% schedule with jax_log_compiles
      captured — zero XLA compiles after slice 1 (delta-overlay store)
      and resident == cold bit-equality per slice, both insert policies
  python -m benchmarks.kernel_bench --serve-smoke   # online-serving gate:
      continuous-batching front-end over the partitioned service, all
      three arrival processes — online == offline bit-exactness (crash
      legs included), zero XLA compiles on every admission tick, and a
      serve-latency.json report (p50/p99 per op class)
  python -m benchmarks.kernel_bench --skew-smoke    # skew-aware placement
      gate: hot-vertex exception-table sweep (0/8/32/128 replicas) on the
      skewed twitter pattern + uniform filesystem control — three-engine
      bit-exactness at every capacity, zero compiles during the sweep,
      >= 20% global-traffic reduction on twitter at 128 replicas
  python -m benchmarks.kernel_bench --traffic --write-baseline       # refresh
  python -m benchmarks.kernel_bench --traffic-dist --write-baseline  # merge
      benchmarks/BENCH_traffic.json ("sharded" section)
  python -m benchmarks.kernel_bench --dynamic --write-baseline       # merge
      benchmarks/BENCH_traffic.json ("dynamic" section)
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import generators


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def bench_rows() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)

    # BSR SpMM oracle vs segment-sum formulation (DiDiC hot path)
    g = generators.two_cluster(n_per=512, p_in=0.05, p_out=0.005, seed=0)
    bell = g.to_block_ell(block_size=128)
    x = jnp.asarray(rng.normal(size=(bell.padded_rows, 128)).astype(np.float32))
    from repro.kernels.bsr_spmm.ref import bell_matmul_ref
    blocks = jnp.asarray(bell.blocks)
    cols = jnp.asarray(bell.block_cols)
    mask = jnp.asarray(bell.block_mask)
    f_bell = jax.jit(lambda x: bell_matmul_ref(blocks, cols, mask, x))
    us = _time(f_bell, x)
    rows.append(f"kernel/bsr_spmm_ref/us_per_call,{us:.1f},N={bell.padded_rows} F=128")
    s, r, w = g.undirected
    sj, rj, wj = jnp.asarray(s), jnp.asarray(r), jnp.asarray(w)
    f_seg = jax.jit(
        lambda x: jax.ops.segment_sum(wj[:, None] * x[rj], sj, num_segments=g.n_nodes)
    )
    xs = x[: g.n_nodes]
    us2 = _time(f_seg, xs)
    rows.append(f"kernel/segment_sum_spmm/us_per_call,{us2:.1f},E={s.shape[0]}")
    # structural: VMEM working set of the Pallas kernel per grid step
    vmem = (128 * 128 + 2 * 128 * 128) * 4
    rows.append(f"kernel/bsr_spmm/vmem_bytes_per_step,{vmem},3 tiles fp32 (<<16MiB)")

    # EmbeddingBag oracle (DIN hot path)
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    table = jnp.asarray(rng.normal(size=(100_000, 18)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 100_000, size=(4096, 100)).astype(np.int32))
    wgt = jnp.ones((4096, 100), jnp.float32)
    f_bag = jax.jit(lambda t, i, w: embedding_bag_ref(t, i, w))
    us3 = _time(f_bag, table, idx, wgt)
    rows.append(f"kernel/embedding_bag_ref/us_per_call,{us3:.1f},B=4096 L=100 D=18")

    # Flash attention oracle vs naive (LM hot path)
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.normal(size=(8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    f_attn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us4 = _time(f_attn, q, k, v)
    rows.append(f"kernel/attention_ref/us_per_call,{us4:.1f},BH=8 T=512 Dh=64 GQA2")

    # Unrolled dynamism scan (dynamic-experiment hot path; ROADMAP tracks
    # the µs/unit figure — the pre-unroll scan sat at ~10 µs/unit on CPU)
    for method, us in scan_us_per_unit().items():
        rows.append(
            f"dynamism/{method}/scan_us_per_unit,{us:.2f},"
            f"4096 units n=50000 k=4 unroll={_scan_unroll()}"
        )
    return rows


def _scan_unroll() -> int:
    from repro.core.dynamic_runtime import _SCAN_UNROLL

    return _SCAN_UNROLL


def scan_us_per_unit(n: int = 50_000, units: int = 4096, k: int = 4,
                     reps: int = 5) -> Dict[str, float]:
    """µs per move unit of the device dynamism scan, per insert method."""
    from repro.core.dynamic_runtime import scan_dynamism_targets

    rng = np.random.default_rng(0)
    parts = rng.integers(0, k, size=n).astype(np.int64)
    movers = rng.integers(0, n, size=units)
    vt = rng.integers(0, 1 << 30, size=n)
    out = {}
    for method, kw in (
        ("fewest_vertices", {}),
        ("least_traffic", {"vertex_traffic": vt}),
    ):
        scan_dynamism_targets(parts, movers, method, k, **kw)  # warm
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            scan_dynamism_targets(parts, movers, method, k, **kw)
            best = min(best, time.perf_counter() - t0)
        out[method] = round(best / units * 1e6, 3)
    return out


# ---------------------------------------------------------------------------
# Traffic engine: batched JIT engine vs scalar oracle (ISSUE 1 tentpole)
# ---------------------------------------------------------------------------
_TRAFFIC_CASES = (
    # pattern, dataset, batched_ops, scalar_sample_ops
    ("filesystem", "filesystem", 100_000, 400),
    ("twitter", "twitter", 100_000, 400),
    ("gis_short", "gis", 20_000, 300),
    ("gis_long", "gis", 4_000, 120),
)

_SMOKE_CASES = (
    ("filesystem", "filesystem", 5_000, 60),
    ("twitter", "twitter", 5_000, 60),
    ("gis_short", "gis", 600, 40),
)


def traffic_bench(
    scale: float = 0.004, smoke: bool = False, reps: int = 3
) -> Dict[str, Dict[str, float]]:
    """ops/sec of the batched engine vs the scalar oracle, per pattern.

    The scalar path runs on a prefix of the same log (it is orders of
    magnitude slower); both paths are verified to agree exactly on that
    prefix before timing counts — a benchmark of a wrong engine is void.
    """
    from repro.core import partitioners
    from repro.core.traffic import OpLog, execute_ops, generate_ops
    from repro.graphs import datasets

    cases = _SMOKE_CASES if smoke else _TRAFFIC_CASES
    reps = 1 if smoke else reps
    out: Dict[str, Dict[str, float]] = {}
    for pattern, dataset, n_batched, n_scalar in cases:
        g = datasets.load(dataset, scale=scale)
        ops = generate_ops(g, n_ops=n_batched, seed=0, pattern=pattern)
        parts = partitioners.random_partition(g.n_nodes, 4, seed=0)
        prefix = OpLog(ops.pattern, ops.starts[:n_scalar], ops.ends[:n_scalar],
                       ops.t_l, ops.t_pg)

        t0 = time.perf_counter()
        ref = execute_ops(g, prefix, parts, 4, engine="scalar")
        scalar_s = time.perf_counter() - t0

        full = execute_ops(g, ops, parts, 4, engine="batched")  # warm + verify
        if not np.array_equal(full.per_op_total[:n_scalar], ref.per_op_total):
            raise AssertionError(f"{pattern}: batched != scalar — benchmark void")
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            execute_ops(g, ops, parts, 4, engine="batched")
            best = min(best, time.perf_counter() - t0)

        out[pattern] = {
            "n_ops": n_batched,
            "scale": scale,
            "batched_ops_per_s": round(n_batched / best, 1),
            "scalar_ops_per_s": round(n_scalar / scalar_s, 1),
            "speedup": round((n_batched / best) / (n_scalar / scalar_s), 2),
        }
    return out


def traffic_rows(results: Dict[str, Dict[str, float]]) -> List[str]:
    rows = []
    for pattern, r in results.items():
        rows.append(
            f"traffic/{pattern}/batched_ops_per_s,{r['batched_ops_per_s']:.0f},"
            f"{r['n_ops']} ops scale={r['scale']}"
        )
        rows.append(
            f"traffic/{pattern}/scalar_ops_per_s,{r['scalar_ops_per_s']:.0f},oracle"
        )
        rows.append(f"traffic/{pattern}/speedup,{r['speedup']:.2f},batched vs scalar")
    return rows


# ---------------------------------------------------------------------------
# Sharded traffic replay: replay_sharded on a data mesh (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------
_DIST_CASES = (
    ("filesystem", "filesystem", 100_000),
    ("twitter", "twitter", 100_000),
    ("gis_short", "gis", 20_000),
    ("gis_long", "gis", 4_000),
)

_DIST_SMOKE_CASES = (
    ("filesystem", "filesystem", 5_000),
    ("gis_short", "gis", 400),
)


def traffic_dist_bench(
    scale: float = 0.004, smoke: bool = False, reps: int = 3
) -> Dict[str, Dict[str, float]]:
    """ops/sec of ``replay_sharded`` on a 1-D data mesh over every visible
    device. Bit-exactness vs the single-device batched engine is asserted
    on all four counters before timing counts. On CPU, shard count comes
    from ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
    Makefile targets set it); on a 1-device platform this degenerates to a
    1-shard mesh and still must be exact.
    """
    from repro.core import partitioners
    from repro.core.traffic import execute_ops, generate_ops
    from repro.core.traffic_sharded import replay_sharded
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    cases = _DIST_SMOKE_CASES if smoke else _DIST_CASES
    reps = 1 if smoke else reps
    out: Dict[str, Dict[str, float]] = {}
    for pattern, dataset, n_ops in cases:
        g = datasets.load(dataset, scale=scale)
        ops = generate_ops(g, n_ops=n_ops, seed=0, pattern=pattern)
        parts = partitioners.random_partition(g.n_nodes, 4, seed=0)

        ref = execute_ops(g, ops, parts, 4, engine="batched")
        got = replay_sharded(g, ops, mesh, parts, 4)  # warm + verify
        for field in ("per_op_total", "per_op_global", "per_partition", "per_vertex"):
            if not np.array_equal(getattr(got, field), getattr(ref, field)):
                raise AssertionError(
                    f"{pattern}: sharded != batched on {field} — benchmark void"
                )
        # resident=False: this bench measures the *cold* sharded solve
        # (comparable across PRs); the resident fold's cross-slice win is
        # what the dynamic bench and resident smoke record.
        best = np.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            replay_sharded(g, ops, mesh, parts, 4, resident=False)
            best = min(best, time.perf_counter() - t0)

        out[pattern] = {
            "n_ops": n_ops,
            "scale": scale,
            "shards": shards,
            "sharded_ops_per_s": round(n_ops / best, 1),
        }
    return out


def traffic_dist_rows(results: Dict[str, Dict[str, float]]) -> List[str]:
    rows = []
    for pattern, r in results.items():
        rows.append(
            f"traffic/{pattern}/sharded_ops_per_s,{r['sharded_ops_per_s']:.0f},"
            f"{r['n_ops']} ops shards={r['shards']} scale={r['scale']} (exact)"
        )
    return rows


# ---------------------------------------------------------------------------
# Dynamic experiment: host loop vs device-resident runtime (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------
def dynamic_bench(
    scale: float = 0.004, smoke: bool = False, n_slices: int = 20
) -> Dict[str, Dict[str, float]]:
    """slices/s of the full dynamism→maintain→replay cycle, host loop vs
    device runtime, on a mesh over every visible device.

    Both runtimes execute the identical schedule (``n_slices`` × 5 %
    slices, ``least_traffic`` insert, intermittent DiDiC every 4th slice)
    with ``maintenance="shared"``, so all four traffic counters must match
    **bit-for-bit** every slice — asserted before timing counts. Timing
    uses fresh runtimes on warmed jit caches, best of two runs. The DiDiC
    config is deliberately narrow (ψ=ρ=3, shallow smoothing): this bench
    measures the *cycle* — dynamism + migration + replay — not diffusion
    width, which ``maintenance_cost`` in benchmarks/paper_tables.py owns.
    """
    from repro.core.didic import DidicConfig, didic_partition
    from repro.core.dynamic_runtime import DynamicExperimentRuntime
    from repro.core.framework import PartitionedGraphService
    from repro.core.traffic import generate_ops
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    n_ops = 5_000 if smoke else 50_000
    amount, maintain_every = 0.05, 4
    g = datasets.load("filesystem", scale=scale)
    ops = generate_ops(g, n_ops=n_ops, seed=0)
    cfg = DidicConfig(k=4, iterations=10, primary_steps=3, secondary_steps=3,
                      smooth_cap=16)
    parts0, _ = didic_partition(g, cfg, seed=0)

    def build(m):
        svc = PartitionedGraphService(
            g, 4, didic=cfg, mesh=m,
            maintenance="shared" if m is not None else "auto",
        )
        svc.partition_with(parts0.copy())
        return DynamicExperimentRuntime(svc, insert_method="least_traffic", seed=0)

    def run(runtime, sink=None):
        return runtime.run(ops, n_slices, amount, maintain_every=maintain_every,
                           on_slice=sink)

    per_slice: Dict[str, list] = {"host": [], "device": []}
    run(build(None), lambda i, r: per_slice["host"].append(r))    # warm host
    run(build(mesh), lambda i, r: per_slice["device"].append(r))  # warm device
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")
    for i, (rh, rd) in enumerate(zip(per_slice["host"], per_slice["device"])):
        for field in fields:
            if not np.array_equal(getattr(rh, field), getattr(rd, field)):
                raise AssertionError(
                    f"dynamic runtime != host loop on slice {i} {field} — "
                    "benchmark void"
                )

    host_s = device_s = np.inf
    for _ in range(2):
        t0 = time.perf_counter()
        run(build(None))
        host_s = min(host_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run(build(mesh))
        device_s = min(device_s, time.perf_counter() - t0)

    scan_us = scan_us_per_unit()
    return {"filesystem": {
        "scale": scale,
        "n_ops": n_ops,
        "n_slices": n_slices,
        "amount": amount,
        "maintain_every": maintain_every,
        "shards": shards,
        "host_slices_per_s": round(n_slices / host_s, 2),
        "device_slices_per_s": round(n_slices / device_s, 2),
        "scan_us_per_unit": scan_us,
        "scan_unroll": _scan_unroll(),
        "parity": True,
    }}


def dynamic_rows(results: Dict[str, Dict[str, float]]) -> List[str]:
    rows = []
    for name, r in results.items():
        note = (f"{r['n_slices']}x{int(r['amount']*100)}% slices "
                f"shards={r['shards']} scale={r['scale']} (bit-exact parity)")
        rows.append(f"dynamic/{name}/host_slices_per_s,{r['host_slices_per_s']},{note}")
        rows.append(f"dynamic/{name}/device_slices_per_s,{r['device_slices_per_s']},{note}")
        for method, us in r.get("scan_us_per_unit", {}).items():
            rows.append(
                f"dynamic/{name}/scan_us_per_unit/{method},{us},"
                f"unroll={r.get('scan_unroll')}"
            )
    return rows


# ---------------------------------------------------------------------------
# Resident replay: cold vs resident bit-equality smoke (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------
def dynamic_resident_smoke(scale: float = 0.004) -> List[str]:
    """Resident-path parity smoke on a mesh over every visible device.

    Replays one log against a dynamically-churned partition map: the
    first replay cold-captures the :class:`ResidentReplayState`, every
    later one takes the resident fold — each compared **bit-for-bit** on
    all four counters against a forced cold solve (``resident=False``).
    A structural-insert leg then dirties two vertices, forcing a partial
    redo through the replicated layout, and compares again. Raises on any
    mismatch; returns rate rows.
    """
    from repro.core import partitioners
    from repro.core.dynamism import apply_dynamism, generate_dynamism
    from repro.core.traffic import generate_ops
    from repro.core.traffic_sharded import (
        get_replayer, migrate_resident_states, replay_sharded,
    )
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")

    def check(got, ref, what: str) -> None:
        for f in fields:
            if not np.array_equal(getattr(got, f), getattr(ref, f)):
                raise AssertionError(f"resident != cold on {what} {f} — smoke void")

    rows = []
    for dataset, pattern, n_ops in (
        ("filesystem", "filesystem", 3_000),
        ("gis", "gis_short", 300),
    ):
        g = datasets.load(dataset, scale=scale)
        ops = generate_ops(g, n_ops=n_ops, seed=0, pattern=pattern)
        parts = partitioners.random_partition(g.n_nodes, 4, seed=0)
        got = replay_sharded(g, ops, mesh, parts, 4)  # cold capture (+compile)
        check(got, replay_sharded(g, ops, mesh, parts, 4, resident=False),
              f"{pattern} slice 0")
        best = cold_best = np.inf
        for i in range(1, 4):
            log = generate_dynamism(parts, 0.05, "random", k=4, seed=i)
            parts = apply_dynamism(parts, log)
            t0 = time.perf_counter()
            got = replay_sharded(g, ops, mesh, parts, 4)  # resident fold
            best = min(best, time.perf_counter() - t0)
            t0 = time.perf_counter()
            ref = replay_sharded(g, ops, mesh, parts, 4, resident=False)
            cold_best = min(cold_best, time.perf_counter() - t0)  # warm cold
            check(got, ref, f"{pattern} slice {i}")
        rows.append(
            f"resident/{pattern}/replay_speedup,{cold_best / best:.2f},"
            f"warm cold {cold_best * 1e3:.1f}ms vs resident {best * 1e3:.1f}ms "
            f"shards={shards} (bit-exact x3 slices)"
        )
        if pattern != "gis_short":
            continue
        # Structural leg: insert an edge touching one op's source — only
        # the touched ops may re-solve, and the result must equal a full
        # cold solve on the updated graph.
        u, v = int(ops.starts[0]), int(ops.ends[-1])
        lon = g.node_attrs["lon"]
        lat = g.node_attrs["lat"]
        w = np.float32(np.hypot(lon[u] - lon[v], lat[u] - lat[v]) + 1e-6)
        g2 = g.with_edges([u], [v], [w])
        migrate_resident_states(ops, g, g2, np.array([u, v]))
        got = replay_sharded(g2, ops, mesh, parts, 4)  # partial redo
        redo = get_replayer(g2, pattern, mesh).last_redo_ops
        check(got, replay_sharded(g2, ops, mesh, parts, 4, resident=False),
              f"{pattern} structural insert")
        if not 0 < redo < n_ops:
            raise AssertionError(
                f"structural redo should be partial, got {redo}/{n_ops}"
            )
        rows.append(
            f"resident/{pattern}/structural_redo_ops,{redo},"
            f"of {n_ops} after 1 edge insert (bit-exact vs cold)"
        )
    return rows


# ---------------------------------------------------------------------------
# Insert experiment: vertex-growth schedule parity smoke (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------
def insert_smoke(scale: Optional[float] = None) -> List[str]:
    """Vertex-growth Insert-workload smoke on a mesh over every visible
    device (the Makefile target forces 8 CPU devices).

    Runs the 20×5 % Insert-experiment schedule — every slice interleaves
    partition moves with *new-vertex* inserts (incident edges + metadata),
    the service grows graph and partition map, and resident replay states
    migrate across each growth — under both sequential insert policies.
    Every slice's resident replay is compared **bit-for-bit** on all four
    counters against a forced cold solve of the grown graph. A second leg
    checks that :meth:`DynamismLog.slice` round-trips a structural log
    exactly: concatenated slices ≡ the whole log, and applying the slices
    in sequence reproduces the whole log's partition map and graph.
    Raises on any mismatch; returns rate rows.
    """
    from repro.core.didic import DidicConfig, didic_partition
    from repro.core.dynamic_runtime import DynamicExperimentRuntime
    from repro.core.dynamism import apply_dynamism, generate_dynamism
    from repro.core.framework import PartitionedGraphService
    from repro.core.traffic import generate_ops
    from repro.core.traffic_sharded import replay_sharded
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    # Default below the other smokes' 0.004: the delta-overlay store keeps
    # compiled shapes stable across growth, but compaction overflows still
    # retrace at the new capacity, and 20 slices × 2 policies is the
    # schedule here.
    scale = 0.002 if scale is None else scale
    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")
    rows = []

    g0 = datasets.load("gis", scale=scale)
    ops = generate_ops(g0, n_ops=150, seed=0, pattern="gis_short")
    cfg = DidicConfig(k=4, iterations=8, primary_steps=3, secondary_steps=3,
                      smooth_cap=16)
    parts0, _ = didic_partition(g0, cfg, seed=0)

    for method in ("fewest_vertices", "least_traffic"):
        svc = PartitionedGraphService(g0, 4, didic=cfg, mesh=mesh,
                                      maintenance="shared")
        svc.partition_with(parts0.copy())
        runtime = DynamicExperimentRuntime(svc, insert_method=method, seed=0)
        mismatches = []

        def check(i, got):
            cold = replay_sharded(svc.graph, ops, mesh, svc.parts, 4,
                                  resident=False)
            for f in fields:
                if not np.array_equal(getattr(got, f), getattr(cold, f)):
                    mismatches.append((i, f))

        t0 = time.perf_counter()
        res = runtime.run(ops, n_slices=20, amount=0.05, maintain_every=4,
                          insert_rate=0.25, on_slice=check)
        wall = time.perf_counter() - t0
        if mismatches:
            raise AssertionError(
                f"{method}: resident != cold on slices {mismatches[:4]} — "
                "smoke void"
            )
        grown = svc.graph.n_nodes - g0.n_nodes
        inserted = sum(r.inserted for r in res.records)
        if grown != inserted or grown <= 0:
            raise AssertionError(
                f"{method}: grew {grown} vertices, log allocated {inserted}"
            )
        if svc.parts.shape[0] != svc.graph.n_nodes:
            raise AssertionError(f"{method}: parts/graph size mismatch")
        rows.append(
            f"insert/{method}/grown_vertices,{grown},"
            f"20x5% insert_rate=0.25 shards={shards} in {wall:.1f}s "
            "(resident bit-exact vs cold every slice)"
        )

    # Structural-slice round-trip: concatenated slices ≡ whole log, and
    # slice-by-slice application reproduces the whole-log parts + graph.
    log = generate_dynamism(parts0, 0.25, "fewest_vertices", k=4, seed=7,
                            insert_rate=0.3, graph=g0)
    pieces, f = [], 0.0
    while f < 1.0 - 1e-12:
        nf = f + 0.05
        pieces.append(log.slice(f, min(nf, 1.0)))
        f = nf
    cat = {
        "vertices": np.concatenate([p.vertices for p in pieces]),
        "targets": np.concatenate([p.targets for p in pieces]),
        "unit_is_insert": np.concatenate([p.unit_is_insert for p in pieces]),
        "insert_senders": np.concatenate([p.insert_senders for p in pieces]),
        "insert_receivers": np.concatenate([p.insert_receivers for p in pieces]),
        "insert_weights": np.concatenate([p.insert_weights for p in pieces]),
    }
    for key, got in cat.items():
        if not np.array_equal(got, getattr(log, key)):
            raise AssertionError(f"slice round-trip lost {key} — smoke void")
    for key, whole_rows in log.insert_attrs.items():
        got = np.concatenate([p.insert_attrs[key] for p in pieces])
        if not np.array_equal(got, whole_rows):
            raise AssertionError(f"slice round-trip lost attrs[{key}] — smoke void")
    parts_seq, g_seq = parts0.copy(), g0
    for p in pieces:
        parts_seq = apply_dynamism(parts_seq, p)
        g_seq = g_seq.with_vertices(p.n_new_vertices, p.insert_attrs,
                                    p.insert_senders, p.insert_receivers,
                                    p.insert_weights)
    g_whole = g0.with_vertices(log.n_new_vertices, log.insert_attrs,
                               log.insert_senders, log.insert_receivers,
                               log.insert_weights)
    if not np.array_equal(parts_seq, apply_dynamism(parts0, log)):
        raise AssertionError("sliced parts != whole-log parts — smoke void")
    same_graph = (
        g_seq.n_nodes == g_whole.n_nodes
        and np.array_equal(g_seq.senders, g_whole.senders)
        and np.array_equal(g_seq.receivers, g_whole.receivers)
        and np.array_equal(g_seq.edge_weight, g_whole.edge_weight)
        and all(np.array_equal(g_seq.node_attrs[k], g_whole.node_attrs[k])
                for k in g_whole.node_attrs)
    )
    if not same_graph:
        raise AssertionError("sliced graph != whole-log graph — smoke void")
    rows.append(
        f"insert/slice_roundtrip/inserts,{log.n_new_vertices},"
        f"20x5% slices of one structural log (exact)"
    )
    return rows


# ---------------------------------------------------------------------------
# Zero-recompile growth: steady-state smoke (ISSUE 8 tentpole)
# ---------------------------------------------------------------------------
def grow_steady_smoke(scale: Optional[float] = None, slices: int = 20):
    """Zero-recompile growth gate on a mesh over every visible device (the
    Makefile target forces 8 CPU devices).

    Runs the recompile sentinel's 20×5 % vertex-growth schedule through
    the service runtime with ``jax_log_compiles`` captured, under BOTH
    sequential insert policies. Two gates, each fatal:

    * **steady state** — XLA compiles *nothing* after slice 1: all
      tracing lands in warm-up (the ``begin`` replay plus slice 0, where
      ``prepare_growth`` attaches the delta-overlay store and traces the
      capacity-shaped programs);
    * **parity** — every slice's resident replay on the grown graph is
      bit-equal on all four counters to a forced cold solve.

    Returns ``(rows, update)`` where ``update`` carries the measured
    steady-state compile cost for the ``dynamic`` section of
    BENCH_traffic.json (``--write-baseline`` merges it).
    """
    from repro.analysis.recompile import capture_compiles, classify
    from repro.core import partitioners
    from repro.core.didic import DidicConfig
    from repro.core.dynamic_runtime import DynamicExperimentRuntime
    from repro.core.framework import PartitionedGraphService
    from repro.core.traffic import generate_ops
    from repro.core.traffic_sharded import replay_sharded
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    scale = 0.002 if scale is None else scale
    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")
    rows: List[str] = []
    update: Dict[str, Dict] = {}

    for method in ("fewest_vertices", "least_traffic"):
        g = datasets.load("filesystem", scale=scale, seed=1)
        svc = PartitionedGraphService(
            g, 4, didic=DidicConfig(k=4, iterations=4), mesh=mesh,
            maintenance="shared",
        )
        svc.partition_with(partitioners.random_partition(g.n_nodes, 4, seed=0))
        ops = generate_ops(g, n_ops=48, seed=3)
        rt = DynamicExperimentRuntime(svc, insert_method=method, seed=0)
        mismatches, per_slice = [], []
        with capture_compiles() as cap:
            cap.slice_label = "warmup"
            rt.begin(ops)
            t_all = time.perf_counter()
            for i in range(slices):
                cap.slice_label = f"slice{i}"
                n0 = len(cap.events)
                t0 = time.perf_counter()
                _, r = rt.run_slice(i, ops, 0.05, maintain_every=6,
                                    insert_rate=0.5)
                cold = replay_sharded(svc.graph, ops, mesh, svc.parts, 4,
                                      resident=False)
                for f in fields:
                    if not np.array_equal(getattr(r, f), getattr(cold, f)):
                        mismatches.append((i, f))
                per_slice.append({"compiles": len(cap.events) - n0,
                                  "seconds": time.perf_counter() - t0})
            wall = time.perf_counter() - t_all
        if mismatches:
            raise AssertionError(
                f"{method}: resident != cold on {mismatches[:4]} — smoke void"
            )
        after_warmup = sum(s["compiles"] for s in per_slice[1:])
        if after_warmup:
            noisy = [r.to_json() for r in classify(cap.events)]
            raise AssertionError(
                f"{method}: {after_warmup} XLA compiles after slice 1 — "
                f"growth must be steady-state: {noisy[:4]}"
            )
        steady_s = [s["seconds"] for s in per_slice[1:]]
        update[method] = {
            "slices": slices, "amount": 0.05, "insert_rate": 0.5,
            "scale": scale, "shards": shards,
            "warmup_compiles": len(cap.events) - after_warmup,
            "compiles_after_warmup": 0,
            "compile_s_per_slice": 0.0,
            "growth_wall_s": round(wall, 2),
            "steady_slice_s": round(float(np.mean(steady_s)), 3),
        }
        grown = svc.graph.n_nodes - g.n_nodes
        rows.append(
            f"grow/{method}/compiles_after_slice1,0,"
            f"{slices}x5% insert_rate=0.5 shards={shards} grew {grown} "
            f"vertices in {wall:.1f}s, steady slice "
            f"{np.mean(steady_s) * 1e3:.0f}ms (resident == cold bit-exact "
            "every slice)"
        )
    return rows, update


def serve_smoke(scale: Optional[float] = None, n_ops: int = 96):
    """Online-serving smoke on a mesh over every visible device (the
    Makefile target forces 8 CPU devices) — the ISSUE 9 acceptance gate.

    For each arrival process (uniform, bursty, skewed-hot) the online
    front-end serves a seeded client stream in fixed-slot admission
    batches with background DiDiC maintenance interleaved, twice: once
    uninterrupted and once under an injected fault plan (admission-loop
    crashes at both ``serve:*`` sites plus a failed shard window). Gates,
    each fatal:

    * **bit-exactness** — online-served counters (per-op per class,
      per-partition, per-vertex) equal :func:`offline_replay` of the
      server's materialized epoch record, AND the crash leg equals the
      uninterrupted leg on all four counters and every latency sample;
    * **zero recompiles** — with every jitted program prewarmed before
      the capture (the explicit warm-up), *no* XLA compile may fire on
      any admission tick of any leg. Op classes are ``filesystem`` and
      ``twitter``: their batched/sharded replays are fixed-shape in op
      *count* only, so distinct batch contents cannot retrace (the GIS
      window solver pads to content-dependent size buckets and would).

    Returns ``(rows, update)``; ``update`` is the ``serving`` section for
    BENCH_traffic.json (throughput + p50/p99 per op class per process).
    The caller always writes the latency report artifact.
    """
    from repro.analysis.recompile import capture_compiles, classify
    from repro.core.didic import DidicConfig, didic_partition, didic_refine
    from repro.core.fault import FaultPlan, SimulatedCrash
    from repro.core.framework import PartitionedGraphService
    from repro.core.online import (
        BackgroundMaintenance,
        OnlineServer,
        inert_pad_op,
        make_arrival_stream,
        offline_replay,
    )
    from repro.core.traffic import OpLog, execute_ops
    from repro.core.traffic_sharded import replay_sharded
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    scale = 0.002 if scale is None else scale
    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    k, slots = 4, 8
    classes = ("filesystem", "twitter")
    # The filesystem graph links files back to their parents, so it has
    # no out-degree-0 vertex for the twitter inert pad — append one
    # isolated parking vertex (typed FS_ORG, degree 0: never a generator
    # start, never sampled, zero on every counter) before partitioning.
    graph = datasets.load("filesystem", scale=scale, seed=1).with_vertices(1)
    cfg = DidicConfig(k=k, iterations=8, primary_steps=3, secondary_steps=3,
                      smooth_cap=16)
    parts0, _ = didic_partition(graph, cfg, seed=0)

    streams = {
        p: make_arrival_stream(graph, classes, n_ops, seed=0, process=p)
        for p in ("uniform", "bursty", "skewed_hot")
    }
    t_counts = streams["uniform"][1]

    # Explicit warm-up: trace every jitted program the serving loop can
    # reach (sharded replay + degraded batched fallback per class at the
    # fixed batch shape, and the maintenance refine) on the shared graph,
    # so the capture below demands strict zero compiles.
    for cls in classes:
        ps, pe = inert_pad_op(graph, cls)
        t_l, t_pg = t_counts[cls]
        warm = OpLog(cls, np.full(slots, ps, np.int64),
                     np.full(slots, pe, np.int64), t_l=t_l, t_pg=t_pg)
        replay_sharded(graph, warm, mesh, parts0, k, resident=False)
        execute_ops(graph, warm, parts0, k, engine="batched")
    didic_refine(graph, parts0, cfg, state=None, iterations=1, seed=0)

    def run_leg(process: str, plan=None):
        svc = PartitionedGraphService(graph, k, didic=cfg, mesh=mesh,
                                      maintenance="shared")
        svc.partition_with(parts0.copy())
        svc.fault_plan = plan
        server = OnlineServer(
            svc, batch_slots=slots, queue_limit=32,
            maintenance=BackgroundMaintenance(svc, every=4,
                                              budget_iterations=1,
                                              round_iterations=2),
            slo={cls: 6 for cls in classes},
        )
        arrivals, tc = streams[process]
        server.submit_stream(arrivals, tc)
        t_all = time.perf_counter()
        with capture_compiles() as cap:
            while not server.drained:
                if server.clock >= 10_000:
                    raise AssertionError(f"{process}: stream never drained")
                cap.slice_label = f"tick{server.clock}"
                t0 = time.perf_counter()
                try:
                    server.tick()
                except SimulatedCrash:
                    svc.logger.record_recovery(time.perf_counter() - t0)
        if cap.events:
            noisy = [r.to_json() for r in classify(cap.events, warmup_labels=())]
            raise AssertionError(
                f"{process}{'+faults' if plan else ''}: {len(cap.events)} XLA "
                f"compiles during admission ticks — serving must be "
                f"steady-state after warm-up: {noisy[:4]}"
            )
        return server.result(), time.perf_counter() - t_all, svc

    rows: List[str] = []
    update: Dict[str, Dict] = {}
    for process in ("uniform", "bursty", "skewed_hot"):
        clean, wall, _ = run_leg(process)
        plan = (FaultPlan()
                .crash(3, site="serve:admit")
                .crash(5, site="serve:commit")
                .fail_shard(1, shard=shards - 1, slices=4))
        crashed, _, csvc = run_leg(process, plan=plan)

        # -- gate: crash leg == clean leg on everything served ---------------
        if crashed.health["recoveries"] != 2:
            raise AssertionError(
                f"{process}: expected 2 crash recoveries, got "
                f"{crashed.health['recoveries']}"
            )
        for cls in classes:
            if not np.array_equal(clean.per_op[cls], crashed.per_op[cls]):
                raise AssertionError(
                    f"{process}: crash leg per-op counters differ on {cls}"
                )
        if not np.array_equal(clean.per_partition, crashed.per_partition):
            raise AssertionError(f"{process}: crash leg per_partition differs")
        if not np.array_equal(clean.per_vertex, crashed.per_vertex):
            raise AssertionError(f"{process}: crash leg per_vertex differs")
        if clean.latency != crashed.latency:
            raise AssertionError(f"{process}: crash leg latency report differs")

        # -- gate: online == offline replay of the epoch record --------------
        for leg_name, leg in (("clean", clean), ("crash", crashed)):
            off_op, off_pp, off_pv = offline_replay(graph, leg.epochs, k,
                                                    t_counts)
            for cls in classes:
                if not np.array_equal(leg.per_op[cls], off_op[cls]):
                    raise AssertionError(
                        f"{process}/{leg_name}: online != offline per-op "
                        f"counters on {cls} — smoke void"
                    )
            if not np.array_equal(leg.per_partition, off_pp):
                raise AssertionError(
                    f"{process}/{leg_name}: online != offline per_partition"
                )
            if not np.array_equal(leg.per_vertex, off_pv):
                raise AssertionError(
                    f"{process}/{leg_name}: online != offline per_vertex"
                )

        per_class = {}
        for cls in classes:
            lat = clean.latency[cls]
            per_class[cls] = {
                "count": lat["count"],
                "queue_wait_p50": lat["queue_wait_p50"],
                "queue_wait_p99": lat["queue_wait_p99"],
                "total_p50": lat["total_p50"],
                "total_p99": lat["total_p99"],
                "slo_budget": lat.get("slo_budget"),
            }
        update[process] = {
            "ops": clean.ops_served,
            "batches": clean.batches_served,
            "ticks": clean.ticks,
            "epochs": len(clean.epochs),
            "shards": shards,
            "batch_slots": slots,
            "throughput_ops_per_s": round(clean.ops_served / wall, 1),
            "slo_violations": clean.health["slo_violations"],
            "classes": per_class,
            "crash_leg": {
                "recoveries": crashed.health["recoveries"],
                "degraded_replays": crashed.health["degraded_replays"],
            },
        }
        rows.append(
            f"serve/{process}/ops,{clean.ops_served},"
            f"{clean.batches_served} batches over {clean.ticks} ticks "
            f"({len(clean.epochs)} parts epochs, shards={shards}, "
            f"0 compiles on every tick, online == offline bit-exact, "
            f"crash leg bit-exact with {crashed.health['recoveries']} "
            f"recoveries / {crashed.health['degraded_replays']} degraded "
            "replays)"
        )
    return rows, update


def skew_smoke(scale: Optional[float] = None, n_ops: int = 96):
    """Skew-aware placement smoke on a mesh over every visible device (the
    Makefile target forces 8 CPU devices) — the ISSUE 10 acceptance gate.

    Sweeps the hot-vertex exception-table size over 0/8/32/128 replicated
    vertices on a DiDiC partitioning of two workloads: the skewed twitter
    pattern (hub reads dominate) and the filesystem pattern as uniform
    control. Per capacity the hot set is chosen from the baseline
    per-vertex traffic via ``select_hot_vertices`` (the same signal the
    runtime's ``refresh_placement`` uses). Gates, each fatal:

    * **parity** — scalar == batched == sharded on all four counters at
      every capacity (replica routing is host-side in every engine);
    * **empty table** — capacity 0 is bit-exact to the pre-placement
      engines (``replicated=None``);
    * **steady state** — after one warm-up replay per graph, the whole
      sweep triggers zero XLA compiles (masks never enter jitted code);
    * **skew win** — >= 20 % global-traffic reduction on twitter at
      <= 128 replicated vertices;
    * **uniform control** — filesystem global traffic never regresses
      (> +1 %) at any capacity.

    Returns ``(rows, update)``; ``update`` is the ``skew`` section of
    BENCH_traffic.json (``--write-baseline`` merges it).
    """
    from repro.analysis.recompile import capture_compiles
    from repro.core.didic import DidicConfig, didic_partition
    from repro.core.partitioners import select_hot_vertices
    from repro.core.traffic import execute_ops, generate_ops
    from repro.core.traffic_sharded import replay_sharded
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    scale = 0.05 if scale is None else scale
    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    k = 8
    capacities = (0, 8, 32, 128)
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")
    rows: List[str] = []
    update: Dict[str, Dict] = {}

    def cv(per_partition: np.ndarray) -> float:
        per_partition = np.asarray(per_partition, dtype=np.float64)
        mean = per_partition.mean()
        return float(per_partition.std() / mean) if mean else 0.0

    for name, pattern in (("twitter", "twitter"), ("filesystem", "filesystem")):
        g = datasets.load(name, scale=scale, seed=0)
        parts, _ = didic_partition(g, DidicConfig(k=k, iterations=25), seed=0)
        parts = np.asarray(parts, dtype=np.int32)
        ops = generate_ops(g, n_ops=n_ops, seed=2, pattern=pattern)
        base = execute_ops(g, ops, parts, k, engine="batched")
        replay_sharded(g, ops, mesh, parts, k)  # warm-up: traces programs

        sweep = {}
        with capture_compiles() as cap:
            for capacity in capacities:
                hot = select_hot_vertices(base.per_vertex, capacity)
                replicated = None
                if hot.size:
                    replicated = np.zeros(g.n_nodes, dtype=bool)
                    replicated[hot] = True
                sc = execute_ops(g, ops, parts, k, engine="scalar",
                                 replicated=replicated)
                bt = execute_ops(g, ops, parts, k, engine="batched",
                                 replicated=replicated)
                sh = replay_sharded(g, ops, mesh, parts, k,
                                    replicated=replicated)
                for f in fields:
                    if not np.array_equal(getattr(sc, f), getattr(bt, f)):
                        raise AssertionError(
                            f"{name}/cap{capacity}: scalar != batched on {f}")
                    if not np.array_equal(getattr(bt, f), getattr(sh, f)):
                        raise AssertionError(
                            f"{name}/cap{capacity}: batched != sharded on {f}")
                if capacity == 0:
                    for f in fields:
                        if not np.array_equal(getattr(bt, f), getattr(base, f)):
                            raise AssertionError(
                                f"{name}: empty exception table is not "
                                f"bit-exact to the pre-placement engine ({f})")
                sweep[capacity] = {
                    "replicated": int(hot.size),
                    "global_traffic": float(bt.per_op_global.sum()),
                    "load_cv": round(cv(bt.per_partition), 4),
                }
        if cap.events:
            raise AssertionError(
                f"{name}: {len(cap.events)} XLA compiles during the capacity "
                "sweep — replica masks must stay host-side")

        g0 = sweep[0]["global_traffic"]
        g128 = sweep[128]["global_traffic"]
        reduction = (g0 - g128) / g0 if g0 else 0.0
        if name == "twitter" and reduction < 0.20:
            raise AssertionError(
                f"twitter: {reduction:.1%} global-traffic reduction at 128 "
                "replicas — need >= 20% vs pure DiDiC")
        worst = max(s["global_traffic"] for s in sweep.values())
        if worst > g0 * 1.01:
            raise AssertionError(
                f"{name}: global traffic regressed {worst / g0 - 1:.2%} "
                "under replication — must stay <= +1%")
        update[name] = {
            "scale": scale, "n_ops": n_ops, "k": k, "shards": shards,
            "didic_iterations": 25,
            "sweep": {str(c): sweep[c] for c in capacities},
            "reduction_at_128": round(reduction, 4),
        }
        rows.append(
            f"skew/{name}/reduction_at_128,{reduction:.3f},"
            f"global {g0:.0f} -> {g128:.0f} over capacities "
            f"{list(capacities)} (load CV {sweep[0]['load_cv']:.3f} -> "
            f"{sweep[128]['load_cv']:.3f}, scalar == batched == sharded at "
            "every capacity, 0 compiles during sweep)"
        )
    return rows, update


def fault_smoke(scale: Optional[float] = None) -> List[str]:
    """Fault-tolerance smoke on a mesh over every visible device (the
    Makefile target forces 8 CPU devices) — the ISSUE 6 acceptance gate.

    Leg 1 (degraded mode): with one mesh shard marked failed, sharded
    replay falls back to the shared single-device engine; the fallback
    must be **bit-equal on all four counters** and the degraded-op count
    must equal the failed shard's contiguous slice of the log.

    Leg 2 (crash recovery): a 12×5 % dynamic schedule with vertex growth
    runs under an injected fault plan — a shard failure spanning two
    slices, a crash between validate and commit of ``apply_dynamism``, a
    crash after commit, and a maintenance timeout retried under backoff.
    Each crash kills the runtime; recovery restores the latest
    snapshot (round-tripped through its durable ``npz`` bytes) and
    replays the write-ahead journal. Every slice of the recovered run —
    all four traffic counters — must match the uninterrupted baseline
    bit-for-bit, as must the final partition map and per-slice records.
    Raises on any mismatch; returns summary rows.
    """
    from repro.core.didic import DidicConfig, didic_partition
    from repro.core.dynamic_runtime import DynamicExperimentRuntime
    from repro.core.fault import FaultPlan, RetryPolicy
    from repro.core.framework import PartitionedGraphService
    from repro.core.recovery import DynamismJournal, run_with_recovery
    from repro.core.traffic import generate_ops
    from repro.graphs import datasets
    from repro.launch.mesh import make_replay_mesh

    scale = 0.002 if scale is None else scale
    mesh = make_replay_mesh()
    shards = len(mesh.devices.flat)
    fields = ("per_op_total", "per_op_global", "per_partition", "per_vertex")
    rows = []

    g0 = datasets.load("gis", scale=scale)
    ops = generate_ops(g0, n_ops=150, seed=0, pattern="gis_short")
    cfg = DidicConfig(k=4, iterations=8, primary_steps=3, secondary_steps=3,
                      smooth_cap=16)
    parts0, _ = didic_partition(g0, cfg, seed=0)

    # -- leg 1: degraded replay under a failed shard ------------------------
    svc = PartitionedGraphService(g0, 4, didic=cfg, mesh=mesh,
                                  maintenance="shared")
    svc.partition_with(parts0.copy())
    healthy = svc.run_ops(ops)
    failed_shard = shards - 1
    svc.mark_shard_failed(failed_shard)
    degraded = svc.run_ops(ops)
    svc.mark_shard_recovered(failed_shard)
    for f in fields:
        if not np.array_equal(getattr(healthy, f), getattr(degraded, f)):
            raise AssertionError(f"degraded replay != healthy on {f} — smoke void")
    b = -(-ops.n_ops // shards)
    want_ops = max(0, min(ops.n_ops, (failed_shard + 1) * b)
                   - min(ops.n_ops, failed_shard * b))
    if svc.logger.degraded_replays != 1 or svc.logger.degraded_ops != want_ops:
        raise AssertionError(
            f"degraded accounting off: {svc.logger.degraded_replays} replays, "
            f"{svc.logger.degraded_ops} ops (want 1 / {want_ops})"
        )
    rows.append(
        f"fault/degraded/ops,{svc.logger.degraded_ops},"
        f"shard {failed_shard}/{shards} down -> shared-engine fallback "
        "(bit-equal all four counters)"
    )

    # -- leg 2: crash recovery bit-exact vs uninterrupted -------------------
    def make_runtime():
        s = PartitionedGraphService(g0, 4, didic=cfg, mesh=mesh,
                                    maintenance="shared")
        s.partition_with(parts0.copy())
        return DynamicExperimentRuntime(s, insert_method="least_traffic", seed=0)

    n_slices = 12
    kw = dict(maintain_every=3, insert_rate=0.2)
    base = {}
    res0 = make_runtime().run(ops, n_slices, 0.05,
                              on_slice=lambda i, r: base.__setitem__(i, r), **kw)

    plan = (FaultPlan()
            .fail_shard(2, shard=1, slices=2)
            .crash(4, site="apply:pre_commit")
            .crash(7, site="apply:post_commit")
            .timeout_maintenance(5, times=2))
    got = {}
    t0 = time.perf_counter()
    res1, stats = run_with_recovery(
        make_runtime, g0, ops, n_slices, 0.05,
        fault_plan=plan, journal=DynamismJournal(),
        retry_policy=RetryPolicy(max_retries=5), snapshot_every=3,
        on_slice=lambda i, r: got.__setitem__(i, r), **kw,
    )
    wall = time.perf_counter() - t0
    if stats.recoveries != 2:
        raise AssertionError(f"expected 2 recoveries, got {stats.recoveries}")
    if stats.journal_rolled_back < 1 or stats.journal_replayed < 1:
        raise AssertionError(f"journal never exercised: {stats}")
    for i in range(n_slices):
        for f in fields:
            if not np.array_equal(getattr(base[i], f), getattr(got[i], f)):
                raise AssertionError(
                    f"recovered run != uninterrupted at slice {i} on {f} — "
                    "smoke void"
                )
    if not np.array_equal(res0.parts, res1.parts):
        raise AssertionError("final partition maps differ — smoke void")
    if res0.records != res1.records:
        raise AssertionError("per-slice records differ — smoke void")
    rows.append(
        f"fault/recovery/slices,{n_slices},"
        f"{stats.recoveries} crashes recovered (snapshots={stats.snapshots_taken}, "
        f"journal replays={stats.journal_replayed}, "
        f"rollbacks={stats.journal_rolled_back}) shards={shards} in {wall:.1f}s "
        "(bit-exact vs uninterrupted on all four counters)"
    )
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--traffic", action="store_true", help="full traffic bench")
    ap.add_argument("--traffic-smoke", action="store_true",
                    help="5-second traffic regression smoke (exactness + rate)")
    ap.add_argument("--traffic-dist", action="store_true",
                    help="sharded replay bench on a mesh over visible devices")
    ap.add_argument("--traffic-dist-smoke", action="store_true",
                    help="10-second sharded replay smoke (exactness + rate)")
    ap.add_argument("--dynamic", action="store_true",
                    help="dynamic-experiment bench: host loop vs device runtime")
    ap.add_argument("--dynamic-smoke", action="store_true",
                    help="dynamic-experiment parity + rate smoke")
    ap.add_argument("--dynamic-resident-smoke", action="store_true",
                    help="resident replay parity smoke (cold vs resident "
                         "bit-equality, incl. structural-insert redo)")
    ap.add_argument("--insert-smoke", action="store_true",
                    help="vertex-growth Insert-workload smoke (20x5% "
                         "schedule, resident vs cold bit-equality under "
                         "both policies + structural slice round-trip)")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="fault-tolerance smoke: degraded-shard replay "
                         "bit-equality + crash recovery (snapshot + "
                         "journal) bit-exact vs an uninterrupted run")
    ap.add_argument("--grow-steady-smoke", action="store_true",
                    help="zero-recompile growth gate: 20x5% vertex-growth "
                         "schedule, zero XLA compiles after slice 1 and "
                         "resident == cold bit-equality per slice, both "
                         "insert policies")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="online-serving gate: all three arrival processes, "
                         "online == offline bit-exactness, crash-leg "
                         "bit-exactness, zero XLA compiles on every "
                         "admission tick; writes serve-latency.json")
    ap.add_argument("--skew-smoke", action="store_true",
                    help="skew-aware placement gate: exception-table sweep "
                         "0/8/32/128 on twitter (skewed) + filesystem "
                         "(uniform control), 3-engine bit-exactness, zero "
                         "compiles during the sweep, >= 20% twitter traffic "
                         "reduction at 128 replicas")
    # None = per-mode default (0.004 everywhere except the insert smoke,
    # which pins 0.002 — see insert_smoke); an explicit value wins always.
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--write-baseline", action="store_true",
                    help="write results to benchmarks/BENCH_traffic.json")
    args = ap.parse_args()
    scale = 0.004 if args.scale is None else args.scale

    baseline_path = os.path.join(os.path.dirname(__file__), "BENCH_traffic.json")

    def write_baseline(update: dict) -> None:
        # Merge, don't overwrite: single-device and sharded sections are
        # produced by different runs (the sharded one under XLA_FLAGS).
        try:
            with open(baseline_path) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            baseline = {}
        baseline.update(update)
        with open(baseline_path, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# baseline written to {baseline_path}")

    if args.traffic or args.traffic_smoke:
        results = traffic_bench(scale=scale, smoke=args.traffic_smoke)
        for row in traffic_rows(results):
            print(row)
        if args.write_baseline:
            if args.traffic_smoke:
                # Smoke runs cover fewer patterns at single-rep timing —
                # writing them would silently degrade the baseline.
                raise SystemExit("--write-baseline requires the full --traffic run")
            write_baseline(results)
    elif args.traffic_dist or args.traffic_dist_smoke:
        results = traffic_dist_bench(scale=scale, smoke=args.traffic_dist_smoke)
        for row in traffic_dist_rows(results):
            print(row)
        if args.write_baseline:
            if args.traffic_dist_smoke:
                raise SystemExit("--write-baseline requires the full --traffic-dist run")
            write_baseline({"sharded": results})
    elif args.insert_smoke:
        for row in insert_smoke(scale=args.scale):
            print(row)
    elif args.fault_smoke:
        for row in fault_smoke(scale=args.scale):
            print(row)
    elif args.grow_steady_smoke:
        rows, update = grow_steady_smoke(scale=args.scale)
        for row in rows:
            print(row)
        if args.write_baseline:
            # Merge under the "dynamic" section next to the pre-overlay
            # numbers so before/after stays one diff.
            try:
                with open(baseline_path) as f:
                    dyn = json.load(f).get("dynamic", {})
            except FileNotFoundError:
                dyn = {}
            # Merge per-policy results; keep the recorded pre-overlay
            # numbers (and any sibling sections) intact.
            dyn.setdefault("growth_steady", {}).update(update)
            write_baseline({"dynamic": dyn})
    elif args.serve_smoke:
        rows, update = serve_smoke(scale=args.scale)
        for row in rows:
            print(row)
        # Always write the latency report artifact (lint-report style:
        # cwd-relative, uploaded by CI) — smoke runs included, so every
        # CI run carries the measured serving latencies.
        with open("serve-latency.json", "w") as f:
            json.dump({"serving": update}, f, indent=2, sort_keys=True)
            f.write("\n")
        print("# latency report written to serve-latency.json")
        if args.write_baseline:
            write_baseline({"serving": update})
    elif args.skew_smoke:
        rows, update = skew_smoke(scale=args.scale)
        for row in rows:
            print(row)
        if args.write_baseline:
            write_baseline({"skew": update})
    elif args.dynamic_resident_smoke:
        for row in dynamic_resident_smoke(scale=scale):
            print(row)
    elif args.dynamic or args.dynamic_smoke:
        results = dynamic_bench(scale=scale, smoke=args.dynamic_smoke)
        for row in dynamic_rows(results):
            print(row)
        if args.write_baseline:
            if args.dynamic_smoke:
                raise SystemExit("--write-baseline requires the full --dynamic run")
            write_baseline({"dynamic": results})
    else:
        for row in bench_rows():
            print(row)


if __name__ == "__main__":
    main()
