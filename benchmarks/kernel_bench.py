"""Kernel microbenchmarks: oracle wall time (CPU) + structural VMEM/roofline
numbers for the Pallas kernels (the TPU target numbers come from §Roofline,
not wall clock — this container is CPU-only)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import generators


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def bench_rows() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)

    # BSR SpMM oracle vs segment-sum formulation (DiDiC hot path)
    g = generators.two_cluster(n_per=512, p_in=0.05, p_out=0.005, seed=0)
    bell = g.to_block_ell(block_size=128)
    x = jnp.asarray(rng.normal(size=(bell.padded_rows, 128)).astype(np.float32))
    from repro.kernels.bsr_spmm.ref import bell_matmul_ref
    blocks = jnp.asarray(bell.blocks)
    cols = jnp.asarray(bell.block_cols)
    mask = jnp.asarray(bell.block_mask)
    f_bell = jax.jit(lambda x: bell_matmul_ref(blocks, cols, mask, x))
    us = _time(f_bell, x)
    rows.append(f"kernel/bsr_spmm_ref/us_per_call,{us:.1f},N={bell.padded_rows} F=128")
    s, r, w = g.undirected
    sj, rj, wj = jnp.asarray(s), jnp.asarray(r), jnp.asarray(w)
    f_seg = jax.jit(
        lambda x: jax.ops.segment_sum(wj[:, None] * x[rj], sj, num_segments=g.n_nodes)
    )
    xs = x[: g.n_nodes]
    us2 = _time(f_seg, xs)
    rows.append(f"kernel/segment_sum_spmm/us_per_call,{us2:.1f},E={s.shape[0]}")
    # structural: VMEM working set of the Pallas kernel per grid step
    vmem = (128 * 128 + 2 * 128 * 128) * 4
    rows.append(f"kernel/bsr_spmm/vmem_bytes_per_step,{vmem},3 tiles fp32 (<<16MiB)")

    # EmbeddingBag oracle (DIN hot path)
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    table = jnp.asarray(rng.normal(size=(100_000, 18)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 100_000, size=(4096, 100)).astype(np.int32))
    wgt = jnp.ones((4096, 100), jnp.float32)
    f_bag = jax.jit(lambda t, i, w: embedding_bag_ref(t, i, w))
    us3 = _time(f_bag, table, idx, wgt)
    rows.append(f"kernel/embedding_bag_ref/us_per_call,{us3:.1f},B=4096 L=100 D=18")

    # Flash attention oracle vs naive (LM hot path)
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.normal(size=(8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4, 512, 64)).astype(np.float32))
    f_attn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us4 = _time(f_attn, q, k, v)
    rows.append(f"kernel/attention_ref/us_per_call,{us4:.1f},BH=8 T=512 Dh=64 GQA2")
    return rows
