"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

Reads ``results/dryrun.jsonl`` (written by launch/dryrun.py) and derives,
per cell, **per-chip**:

    compute term    = HLO_FLOPs / peak_FLOPs        (197 TFLOP/s bf16)
    memory term     = HLO_bytes / HBM_bw            (819 GB/s)
    collective term = collective_bytes / link_bw    (~50 GB/s/link ICI)

HLO_FLOPs / bytes come from the compiled module's cost_analysis (per-device
— verified against hand-counted matmuls); collective bytes are the summed
output sizes of all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute ops in the post-SPMD optimized HLO. Scanned layer
bodies are probe-corrected (see launch/dryrun.py).

MODEL_FLOPS uses 6·N·D (dense train) / 6·N_active·D (MoE) / 2·N·D
(inference) + the attention-KV term; the ratio MODEL_FLOPS/HLO_FLOPs is
the "useful compute" fraction (catches remat/replication waste).

roofline_fraction = time(MODEL_FLOPS at peak) / max(three terms) — the
headline per-cell performance score (§Perf optimizes it).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

LM_SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
}


def model_flops(rec: Dict) -> Optional[float]:
    """Analytic useful FLOPs per device for the cell, or None."""
    meta = rec.get("meta") or {}
    n_dev = rec["n_devices"]
    shape = rec["shape"]
    params = meta.get("params")
    if params:  # LM family
        active = meta.get("active_params", params)
        toks = LM_SHAPE_TOKENS.get(shape)
        if toks is None:
            return None
        if rec["kind"] == "train":
            return 6.0 * active * toks / n_dev
        if rec["kind"] == "prefill":
            return 2.0 * active * toks / n_dev
        if rec["kind"] == "decode":
            # fwd matmuls + attention over the 32k KV cache
            kv = 32768
            # attention: 2 matmuls × 2 flops × B × kv × d_attn per layer —
            # fold in as 4·B·kv·params_attn_share ≈ use 15% of param flops
            return (2.0 * active * toks + 0.6 * active * toks * kv / 8192) / n_dev
    try:  # GNN / recsys families: hand-derived formulas in configs.base
        from repro.configs.base import analytic_model_flops

        return analytic_model_flops(rec["arch"], shape, n_dev)
    except Exception:  # noqa: BLE001 — roofline must degrade gracefully
        return None


def three_terms(rec: Dict) -> Dict[str, float]:
    corr = rec.get("corrected") or {}
    flops = corr.get("flops") or rec["cost"]["flops"]
    bytes_acc = corr.get("bytes_accessed") or rec["cost"]["bytes_accessed"]
    coll = corr.get("collective_bytes")
    if coll is None:
        coll = rec["collectives"]["total_bytes"]
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll / LINK_BW,
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "collective_bytes": coll,
    }


def analyze(path: str = "results/dryrun.jsonl") -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("status") != "ok":
                continue
            seen[(rec["arch"], rec["shape"], rec["mesh"])] = rec  # last wins
    for (arch, shape, mesh), rec in sorted(seen.items()):
        t = three_terms(rec)
        dominant = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
        mf = model_flops(rec)
        ratio = (mf / t["hlo_flops"]) if (mf and t["hlo_flops"]) else None
        bound_s = t[dominant]
        frac = (mf / PEAK_FLOPS) / bound_s if (mf and bound_s > 0) else None
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh, "kind": rec["kind"],
            **{k: t[k] for k in ("compute_s", "memory_s", "collective_s")},
            "dominant": dominant.replace("_s", ""),
            "model_flops": mf,
            "useful_ratio": ratio,
            "roofline_fraction": frac,
            "peak_mem_gb": rec["memory"]["peak_bytes"] / rec["n_devices"] / 1e9
            if rec["memory"]["peak_bytes"] else None,
        })
    return rows


def recommendation(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return "reduce cross-shard bytes: better placement/sharding, overlap collectives with compute"
    if d == "memory":
        return "raise arithmetic intensity: fuse ops, wider tiles, bf16 activations, fewer materializations"
    ratio = row.get("useful_ratio")
    if ratio is not None and ratio < 0.6:
        return "compute-bound but wasteful: cut remat recompute / SPMD replication"
    return "compute-bound near-useful: increase per-chip batch or accept"


def rows_csv(path: str = "results/dryrun.jsonl") -> List[str]:
    out = ["cell,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_fraction"]
    for r in analyze(path):
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},{r['collective_s']:.3e},"
            f"{r['dominant']},"
            f"{'' if r['useful_ratio'] is None else round(r['useful_ratio'], 3)},"
            f"{'' if r['roofline_fraction'] is None else round(r['roofline_fraction'], 3)}"
        )
    return out


def markdown_table(path: str = "results/dryrun.jsonl", mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac | what moves it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in analyze(path):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {('%.2f' % r['useful_ratio']) if r['useful_ratio'] else '—'} "
            f"| {('%.3f' % r['roofline_fraction']) if r['roofline_fraction'] else '—'} "
            f"| {recommendation(r)} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
