"""Paper-table reproductions — one function per table/figure (deliverable d).

Emits ``name,value,derived`` CSV rows (benchmarks/run.py contract) plus a
summary dict consumed by EXPERIMENTS.md. Default scale runs the CPU box in
minutes; ``--scale`` raises toward paper sizes.

  table_7_1          edge cut per dataset × method × k
  tables_7_2_to_7_4  load-balance CV (traffic / vertices / edges)
  static_traffic     Figs 7.1–7.3: T_G% per method + reduction vs random
  correlation_check  Eq. 7.3 predicted vs measured T_G%
  insert_experiment  §7.4: dynamism levels × insert methods
  insert_growth_experiment  §7.4 with write-time vertex allocation:
                     quality/balance vs insert rate per policy
  stress_experiment  §7.5: one DiDiC iteration repairs 25 % dynamism
  dynamic_experiment §7.6: intermittent DiDiC under ongoing dynamism
  maintenance_cost   §Abstract: maintenance ≈ 1 % of initial partitioning

The Stress and Dynamic experiments drive
:class:`repro.core.dynamic_runtime.DynamicExperimentRuntime`; pass a
``mesh`` to run every leg of their cycle on the device engines (sharded
replay + device-scan dynamism + mesh DiDiC) — that is how the §7.6
curves run at paper scale on a multi-host mesh. On the mesh path each
per-slice measurement replays through the device-resident
:class:`~repro.core.traffic_sharded.ResidentReplayState` (bit-identical
to a cold solve), so the measurement loop itself stays a small fraction
of the cycle — the premise behind the paper's ~1 % maintenance-cost
headline; ``dynamic/<ds>/cycle_s`` rows record the wall clock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.paper_didic import PaperExperimentConfig
from repro.core import metrics, partitioners
from repro.core.didic import DidicConfig, didic_partition, didic_refine
from repro.core.dynamic_runtime import DynamicExperimentRuntime
from repro.core.dynamism import apply_dynamism, generate_dynamism
from repro.core.framework import PartitionedGraphService
from repro.core.traffic import execute_ops, generate_ops
from repro.graphs import datasets


@dataclasses.dataclass
class Row:
    name: str
    value: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.value},{self.derived}"


class PaperBench:
    """Caches graphs / op logs / partitionings across the table functions."""

    def __init__(self, cfg: Optional[PaperExperimentConfig] = None):
        self.cfg = cfg or PaperExperimentConfig()
        self._graphs = {}
        self._ops = {}
        self._parts = {}

    # ------------------------------------------------------------- caching
    def graph(self, name: str):
        if name not in self._graphs:
            self._graphs[name] = datasets.load(name, scale=self.cfg.scale, seed=self.cfg.seed)
        return self._graphs[name]

    def ops(self, name: str):
        if name not in self._ops:
            n = self.cfg.n_ops_gis if name == "gis" else self.cfg.n_ops
            self._ops[name] = generate_ops(self.graph(name), n_ops=n, seed=self.cfg.seed)
        return self._ops[name]

    def partition(self, name: str, method: str, k: int) -> np.ndarray:
        key = (name, method, k)
        if key not in self._parts:
            g = self.graph(name)
            if method == "random":
                p = partitioners.random_partition(g.n_nodes, k, seed=self.cfg.seed)
            elif method == "didic":
                p, state = didic_partition(g, self.cfg.didic(name, k), seed=self.cfg.seed)
                self._parts[(name, "didic_state", k)] = state
            elif method == "hardcoded":
                p = partitioners.hardcoded_for(g, k)
                if p is None:
                    return None
            else:
                raise KeyError(method)
            self._parts[key] = p
        return self._parts[key]

    def methods_for(self, name: str) -> List[str]:
        return ["random", "didic"] + ([] if name == "twitter" else ["hardcoded"])

    # ------------------------------------------------------------- tables
    def table_7_1(self) -> List[Row]:
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            for k in self.cfg.partition_counts:
                for method in self.methods_for(name):
                    p = self.partition(name, method, k)
                    ec = metrics.edge_cut_fraction(g, p)
                    rows.append(Row(f"table7.1/{name}/k{k}/{method}/edge_cut_pct", round(ec * 100, 2)))
        return rows

    def tables_7_2_to_7_4(self) -> List[Row]:
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            ops = self.ops(name)
            for k in self.cfg.partition_counts:
                for method in self.methods_for(name):
                    p = self.partition(name, method, k)
                    res = execute_ops(g, ops, p, k)
                    counts = metrics.partition_counts(g, p, k)
                    for what, vals in (
                        ("traffic", res.per_partition),
                        ("vertices", counts["vertices"]),
                        ("edges", counts["edges"]),
                    ):
                        cv = metrics.coefficient_of_variation(vals)
                        rows.append(
                            Row(f"table7.2-4/{name}/k{k}/{method}/cv_{what}_pct", round(cv * 100, 2))
                        )
        return rows

    def static_traffic(self) -> List[Row]:
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            ops = self.ops(name)
            for k in self.cfg.partition_counts:
                base = None
                for method in self.methods_for(name):
                    p = self.partition(name, method, k)
                    res = execute_ops(g, ops, p, k)
                    pg = res.percent_global
                    rows.append(Row(f"fig7.1-3/{name}/k{k}/{method}/percent_global", round(pg * 100, 3)))
                    if method == "random":
                        base = pg
                    else:
                        red = (1 - pg / base) * 100 if base else 0.0
                        rows.append(
                            Row(
                                f"fig7.1-3/{name}/k{k}/{method}/traffic_reduction_pct",
                                round(red, 1),
                                "paper: DiDiC 40-90% vs random",
                            )
                        )
        return rows

    def correlation_check(self) -> List[Row]:
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            ops = self.ops(name)
            for k in self.cfg.partition_counts:
                p = self.partition(name, "random", k)
                ec = metrics.edge_cut_fraction(g, p)
                measured = execute_ops(g, ops, p, k).percent_global
                predicted = metrics.expected_global_traffic(ops.t_pg, ops.t_l, ec)
                rows.append(Row(f"eq7.3/{name}/k{k}/measured", round(measured * 100, 3)))
                rows.append(Row(f"eq7.3/{name}/k{k}/predicted", round(predicted * 100, 3)))
                rel = abs(measured - predicted) / max(predicted, 1e-9)
                rows.append(Row(f"eq7.3/{name}/k{k}/rel_error", round(rel, 4), "paper: close match"))
        return rows

    def insert_experiment(self, k: int = 4) -> List[Row]:
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            ops = self.ops(name)
            base = self.partition(name, "didic", k)
            base_res = execute_ops(g, ops, base, k)
            for method in ("random", "fewest_vertices", "least_traffic"):
                for level in self.cfg.dynamism_levels:
                    log = generate_dynamism(
                        base, level, method, k=k,
                        vertex_traffic=base_res.per_vertex, seed=self.cfg.seed,
                    )
                    p2 = apply_dynamism(base, log)
                    res = execute_ops(g, ops, p2, k)
                    rows.append(
                        Row(
                            f"insert/{name}/{method}/dyn{int(level*100)}/percent_global",
                            round(res.percent_global * 100, 3),
                        )
                    )
                    rows.append(
                        Row(
                            f"insert/{name}/{method}/dyn{int(level*100)}/cv_traffic_pct",
                            round(metrics.coefficient_of_variation(res.per_partition) * 100, 2),
                        )
                    )
        return rows

    def insert_growth_experiment(self, k: int = 4, mesh=None,
                                 n_slices: int = 4, amount: float = 0.05) -> List[Row]:
        """§7.4's Insert experiment with *write-time vertex allocation*
        (Tables 7.5-style): traffic quality and balance vs insert rate,
        per insert policy. Each run drives the dynamic cycle with
        ``insert_rate`` of every slice's units allocating a new vertex
        (plus incident edges) on the evolving graph — the service grows
        graph and partition map, resident replay states migrate across
        each growth, and intermittent DiDiC maintains the grown graph.
        Rows record the final T_G%, the served-traffic balance CV, and
        the realized vertex growth.
        """
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            for method in ("random", "fewest_vertices", "least_traffic"):
                for rate in (0.1, 0.3):
                    runtime = self._runtime_for(name, k, method, mesh=mesh)
                    res = runtime.run(
                        self.ops(name), n_slices=n_slices, amount=amount,
                        maintain_every=2, insert_rate=rate,
                    )
                    svc = runtime.service
                    grown = svc.graph.n_nodes - g.n_nodes
                    tag = f"insert_growth/{name}/{method}/rate{int(rate * 100)}"
                    rows.append(Row(
                        f"{tag}/percent_global",
                        round(res.final.percent_global * 100, 3),
                        "paper: repartitioning holds quality under inserts",
                    ))
                    rows.append(Row(
                        f"{tag}/cv_traffic_pct",
                        round(metrics.coefficient_of_variation(
                            res.final.per_partition) * 100, 2),
                    ))
                    rows.append(Row(f"{tag}/grown_vertices", grown))
        return rows

    def _runtime_for(self, name: str, k: int, insert_method: str, mesh=None,
                     maintenance: str = "auto",
                     carry_state: bool = True) -> DynamicExperimentRuntime:
        """Service + runtime on a cached DiDiC partitioning.

        ``mesh`` flips every leg of the cycle onto the device engines
        (sharded replay, device-scan dynamism, mesh DiDiC per
        ``maintenance``); the default host path is the CPU-box reference.
        ``carry_state`` seeds maintenance from the initial partitioning's
        diffusion state (the Dynamic experiment's warm maintenance); the
        Stress experiment measures the paper's *cold* one-iteration
        repair and passes ``False``.
        """
        g = self.graph(name)
        parts = self.partition(name, "didic", k)  # also fills the state cache
        svc = PartitionedGraphService(
            g, k, didic=self.cfg.didic(name, k), mesh=mesh, maintenance=maintenance
        )
        if carry_state and not (mesh is not None and maintenance in ("auto", "sharded")):
            svc.runtime.state = self._parts.get((name, "didic_state", k))
        svc.partition_with(parts.copy())
        return DynamicExperimentRuntime(svc, insert_method=insert_method,
                                        seed=self.cfg.seed)

    def stress_experiment(self, k: int = 4, mesh=None,
                          maintenance: str = "auto") -> List[Row]:
        """``maintenance="shared"`` keeps the bit-parity single-device
        DiDiC on a mesh whose shard count doesn't divide ``k`` (the
        sharded DiDiC requires k % shards == 0); replay and dynamism still
        run on the mesh."""
        rows = []
        for name in self.cfg.datasets:
            runtime = self._runtime_for(name, k, "random", mesh=mesh,
                                        maintenance=maintenance,
                                        carry_state=False)
            res = runtime.run(self.ops(name), n_slices=1, amount=0.25,
                              maintain_every=1, measure_damaged=True)
            rec = res.records[0]
            rows += [
                Row(f"stress/{name}/base_pg", round(res.baseline.percent_global * 100, 3)),
                Row(f"stress/{name}/damaged_pg", round(rec.damaged_percent_global * 100, 3)),
                Row(f"stress/{name}/repaired_pg", round(rec.percent_global * 100, 3),
                    "paper: 1 iteration repairs 25% dynamism"),
            ]
        return rows

    def dynamic_experiment(self, k: int = 4, mesh=None,
                           insert_method: str = "random",
                           maintenance: str = "auto") -> List[Row]:
        """See :meth:`stress_experiment` for the ``maintenance`` knob."""
        rows = []
        for name in self.cfg.datasets:
            runtime = self._runtime_for(name, k, insert_method, mesh=mesh,
                                        maintenance=maintenance)
            t0 = time.perf_counter()
            res = runtime.run(self.ops(name), n_slices=5, amount=0.05,
                              maintain_every=1)
            cycle_s = time.perf_counter() - t0
            for rec in res.records:
                rows.append(Row(
                    f"dynamic/{name}/round{rec.index+1}/percent_global",
                    round(rec.percent_global * 100, 3),
                    "paper: quality maintained under ongoing dynamism",
                ))
                rows.append(Row(
                    f"dynamic/{name}/round{rec.index+1}/migrated_vertices",
                    rec.migrated,
                ))
            rows.append(Row(
                f"dynamic/{name}/cycle_s", round(cycle_s, 2),
                "5 slices incl. baseline replay"
                + (" (resident device replay)" if mesh is not None else ""),
            ))
        return rows

    def maintenance_cost(self, k: int = 4) -> List[Row]:
        """Wall-clock ratio of 1 maintenance iteration vs initial T=100.

        Compilation is warmed first (the step function is cached per graph)
        so the ratio compares *computation*, as the paper does.
        """
        rows = []
        for name in self.cfg.datasets:
            g = self.graph(name)
            cfg = self.cfg.didic(name, k)
            didic_refine(  # warm-up: trace + compile the cached step
                g, partitioners.random_partition(g.n_nodes, k, self.cfg.seed),
                cfg, iterations=1,
            )
            t0 = time.perf_counter()
            parts, state = didic_partition(g, cfg, seed=self.cfg.seed)
            t_init = time.perf_counter() - t0
            t0 = time.perf_counter()
            didic_refine(g, parts, cfg, state=state, iterations=1)
            t_one = time.perf_counter() - t0
            ratio = t_one / max(t_init, 1e-9)
            rows.append(Row(f"maintenance/{name}/cost_ratio_pct", round(ratio * 100, 2),
                            "paper: ~1% of initial partitioning"))
        return rows

    def all_tables(self) -> List[Row]:
        rows = []
        for fn in (
            self.table_7_1, self.tables_7_2_to_7_4, self.static_traffic,
            self.correlation_check, self.insert_experiment,
            self.insert_growth_experiment, self.stress_experiment,
            self.dynamic_experiment, self.maintenance_cost,
        ):
            t0 = time.perf_counter()
            rows += fn()
            rows.append(Row(f"_timing/{fn.__name__}_s", round(time.perf_counter() - t0, 1)))
        return rows
