import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing harness: named variants of the three chosen cells,
each compiled on the single-pod production mesh and measured with the same
machinery as the baseline dry-run. Appends records to results/perf.jsonl.

Chosen cells (from the baseline roofline table):
  1. granite-3-8b × train_4k   — representative dense-LM train cell
     (variants: attention layout, remat policy)
  2. qwen3-moe-30b-a3b × train_4k — most collective-bound cell (399 s
     collective term; variants: dense dispatch vs shard_map local EP)
  3. gcn-cora × ogb_products   — most paper-representative cell
     (variants: XLA auto-sharded message passing vs DiDiC-placed halo
     exchange vs random-placed halo exchange — the paper's claim in
     roofline units)

Usage: PYTHONPATH=src:. python benchmarks/perf_iterations.py [--only NAME]
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import collective_stats


def _measure(step_fn, abstract_args, in_specs, mesh, probe=None):
    from repro.distributed.sharding import to_shardings

    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(step_fn, in_shardings=to_shardings(mesh, in_specs)).lower(*abstract_args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
    rec = {
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total_bytes"],
        "collective_count": coll["total_count"],
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
    }
    if probe is not None:
        # layer probe correction (see launch/dryrun.py)
        l_total, spec1, spec2 = probe
        r1 = _measure(spec1.step_fn, spec1.abstract_args, spec1.in_specs, mesh)
        r2 = _measure(spec2.step_fn, spec2.abstract_args, spec2.in_specs, mesh)
        for key in ("flops", "bytes_accessed", "collective_bytes"):
            rec[key] = r1[key] + (l_total - 1) * (r2[key] - r1[key])
    return rec


# ---------------------------------------------------------------- LM cells
def lm_variant(arch_module, shape, mesh, **overrides):
    from repro.configs import base

    full = dataclasses.replace(arch_module.FULL, **overrides)
    spec = base.lm_dryrun(full, shape, mesh)
    spec1 = base.lm_dryrun(full, shape, mesh, n_layers_override=1)
    spec2 = base.lm_dryrun(full, shape, mesh, n_layers_override=2)
    return _measure(spec.step_fn, spec.abstract_args, spec.in_specs, mesh,
                    probe=(full.n_layers, spec1, spec2))


# ---------------------------------------------------------------- GCN cell
def measure_products_halo_stats(scale: float = 0.01, n_shards: int = 16) -> dict:
    """Measure placement statistics on a reduced products-like graph.

    Returns, per placement method, the edge-cut fraction, the boundary-node
    fraction (drives the all-gather halo), and the max pairwise ghost count
    (drives the all-to-all halo) — all as *fractions of block size* so they
    scale to the full ogbn-products dimensions.
    """
    from repro.core import metrics, partitioners
    from repro.core.didic import DidicConfig, didic_partition
    from repro.distributed.placement import build_layout
    from repro.graphs import datasets

    g = datasets.load("products_like", scale=scale)
    out = {"n_nodes": g.n_nodes}
    did, _ = didic_partition(g, DidicConfig(k=n_shards, iterations=40), seed=0)
    rand = partitioners.random_partition(g.n_nodes, n_shards, seed=0)
    s_arr, r_arr, _ = g.undirected
    for name, parts in (("random", rand), ("didic", did)):
        layout = build_layout(g, parts, n_shards)
        shard_s = layout.old_to_new[s_arr] // layout.block
        shard_r = layout.old_to_new[r_arr] // layout.block
        cross = shard_s != shard_r
        # boundary fraction: nodes exporting to any foreign shard
        boundary = np.unique(s_arr[cross]).shape[0] / g.n_nodes
        # pairwise ghosts: unique (sender, dst-shard) pairs, max over pairs
        pair_key = (s_arr[cross].astype(np.int64) * n_shards + shard_r[cross])
        per_pair = np.bincount(
            np.unique(pair_key) % n_shards
            + (np.unique(pair_key) // n_shards % n_shards) * n_shards,
            minlength=n_shards * n_shards,
        )
        out[name] = {
            "cut": metrics.edge_cut_fraction(g, parts),
            "boundary_frac": float(boundary),
            "pair_ghost_frac": float(per_pair.max() / g.n_nodes),
        }
    return out


def gcn_products_halo_variant(mesh, stats: dict, exchange: str):
    """gcn-cora × ogb_products with halo-exchange message passing.

    ``exchange``: 'all_gather' broadcasts each shard's boundary rows to all
    shards (volume S·B_max·F — cheap only when boundaries are small);
    'all_to_all' sends each shard pair only its ghosts (volume S·Hp·F ∝
    edge cut). Table shapes derive from *measured* placement statistics on
    the reduced graph; index tables are ShapeDtypeStructs — lowering needs
    shapes only, and collective volume depends only on them.
    """
    from repro.optim import adamw
    n, e_dir, d_feat, d_hidden, n_cls = 2_449_029, 61_859_140, 100, 16, 7
    from repro.distributed.sharding import batch_axes
    data_axes = batch_axes(mesh)
    S = 1
    for a in data_axes:
        S *= mesh.shape[a]
    block = -(-n // S // 8) * 8
    e_sym = 2 * e_dir
    e_max = -(-e_sym // S // 8) * 8 + 64
    b_max = max(int(stats["boundary_frac"] * n / S) + 8, 16)
    hp_max = max(int(stats["pair_ghost_frac"] * n) + 8, 16)  # per shard pair
    g_max = min(int(stats["cut"] * e_sym / S) + 64, e_max)

    sds = jax.ShapeDtypeStruct
    batch = {
        "x": sds((S * block, d_feat), jnp.float32),
        "labels": sds((S * block,), jnp.int32),
        "edge_src": sds((S, e_max), jnp.int32),
        "edge_dst": sds((S, e_max), jnp.int32),
        "edge_w": sds((S, e_max), jnp.float32),
        "edge_mask": sds((S, e_max), jnp.float32),
        "ghost_src": sds((S, g_max), jnp.int32),
    }
    if exchange == "all_gather":
        batch["boundary_idx"] = sds((S, b_max), jnp.int32)
    else:
        batch["pair_send_idx"] = sds((S, S, hp_max), jnp.int32)
    bspecs = {k: (P(data_axes) if v.ndim == 1 and k == "labels" else P(data_axes, *([None] * (v.ndim - 1))))
              for k, v in batch.items()}
    dims = [d_feat, d_hidden, n_cls]
    params = {f"w{i}": sds((dims[i], dims[i + 1]), jnp.float32) for i in range(2)}
    pspecs = {k: P() for k in params}
    opt_abs = {"m": params, "v": params, "step": sds((), jnp.int32)}
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    opt_cfg = adamw.AdamWConfig()

    def spmm_body(h, esrc, edst, ew, emask, gsrc, *exchange_tabs):
        h = h.reshape(block, -1)
        f = h.shape[1]
        if exchange == "all_gather":
            (bidx,) = exchange_tabs
            boundary = h[bidx[0]]
            pool = jax.lax.all_gather(boundary, data_axes, tiled=False).reshape(-1, f)
        else:
            (psend,) = exchange_tabs
            send = h[psend[0].reshape(-1)].reshape(S, hp_max, f)
            pool = jax.lax.all_to_all(
                send, data_axes, split_axis=0, concat_axis=0, tiled=False
            ).reshape(-1, f)
        ghosts = pool[gsrc[0]]
        hh = jnp.concatenate([h, ghosts], axis=0)
        contrib = (ew[0] * emask[0])[:, None] * hh[esrc[0]]
        return jax.ops.segment_sum(contrib, edst[0], num_segments=block)

    n_tabs = 6
    smap = jax.shard_map(
        spmm_body,
        in_specs=(P(data_axes, None),) + tuple(
            P(data_axes, *([None] * nd)) for nd in ([1] * 5 + ([1] if exchange == "all_gather" else [2]))
        ),
        out_specs=P(data_axes, None),
        check_vma=False,
    )

    def train_step(params, opt_state, batch):
        tabs = [batch["edge_src"], batch["edge_dst"], batch["edge_w"],
                batch["edge_mask"], batch["ghost_src"]]
        tabs.append(batch["boundary_idx"] if exchange == "all_gather" else batch["pair_send_idx"])

        def loss_f(p):
            h = batch["x"]
            for i in range(2):
                h = h @ p[f"w{i}"]
                h = smap(h, *tabs) + h
                if i == 0:
                    h = jax.nn.relu(h)
            logp = jax.nn.log_softmax(h.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
            return nll.mean()

        loss, grads = jax.value_and_grad(loss_f)(params)
        params, opt_state, _ = adamw.update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    return _measure(train_step, (params, opt_abs, batch), (pspecs, ospecs, bspecs), mesh), dict(
        S=S, block=block, b_max=b_max, hp_max=hp_max, g_max=g_max, cut=stats["cut"],
        exchange=exchange,
    )


VARIANTS = {}


def variant(name):
    def deco(fn):
        VARIANTS[name] = fn
        return fn
    return deco


@variant("granite_train4k_flat_attn")
def _v1(mesh):
    from repro.configs import granite_3_8b as m
    return lm_variant(m, "train_4k", mesh, attn_flat_layout=True)


@variant("granite_train4k_bthd_attn")
def _v2(mesh):
    from repro.configs import granite_3_8b as m
    return lm_variant(m, "train_4k", mesh)


@variant("granite_train4k_bthd_noremat")
def _v3(mesh):
    from repro.configs import granite_3_8b as m
    return lm_variant(m, "train_4k", mesh, remat=False)


@variant("qwen3_train4k_dense_dispatch")
def _v4(mesh):
    from repro.configs import qwen3_moe_30b_a3b as m
    return lm_variant(m, "train_4k", mesh)


@variant("qwen3_train4k_ep_shardmap")
def _v5(mesh):
    from repro.configs import qwen3_moe_30b_a3b as m
    return lm_variant(m, "train_4k", mesh, moe_impl="ep_shardmap")


@variant("qwen3_train4k_ep_shardmap_noremat")
def _v6(mesh):
    from repro.configs import qwen3_moe_30b_a3b as m
    return lm_variant(m, "train_4k", mesh, moe_impl="ep_shardmap", remat=False)


@variant("gcn_products_halo")
def _v7(mesh):
    stats = measure_products_halo_stats()
    out = {"measured_stats": stats}
    for method in ("random", "didic"):
        for exchange in ("all_gather", "all_to_all"):
            rec, meta = gcn_products_halo_variant(mesh, stats[method], exchange)
            out[f"halo_{method}_{exchange}"] = {**rec, **meta}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--out", type=str, default="results/perf.jsonl")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for name, fn in VARIANTS.items():
            if args.only and args.only not in name:
                continue
            print(f"[perf] {name} ...", flush=True)
            try:
                rec = fn(mesh)
                rec["variant"] = name
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                rec = {"variant": name, "status": "fail", "error": str(e)[:500]}
            print(f"[perf] {name}: {json.dumps(rec)[:400]}")
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
